//! Serving-loop integration: boot the coordinator on an ephemeral port and
//! speak the JSON-lines protocol over real TCP — against a geometry-only
//! reference bundle, so the full request path (TCP -> queue -> worker pool
//! -> engine -> response) executes on every `cargo test` with no XLA
//! toolchain and no `make artifacts`.

use mafat::coordinator::{
    auto_config_from_manifest, ladder_from_manifest, sample_rss_bytes, GovernorConfig,
    MemoryGovernor, ModelSpec, QosClass, ServeHooks, Server, ServerConfig, TenantSpec,
};
use mafat::engine::Engine;
use mafat::jsonlite::Json;
use mafat::network::{LayerKind, Network, MIB};
use mafat::plan::MultiConfig;
use mafat::predictor::{predict_multi, PredictorParams};
use mafat::runtime::export::{write_reference_bundle, ExportSpec};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

fn conv(filters: usize, size: usize) -> LayerKind {
    LayerKind::Conv {
        filters,
        size,
        stride: 1,
        pad: size / 2,
    }
}

fn maxpool() -> LayerKind {
    LayerKind::MaxPool { size: 2, stride: 2 }
}

/// A small conv/pool net (32x32x3 -> 8x8x16) that keeps per-request work
/// in the low-millisecond range, so pool/concurrency tests stay fast.
fn tiny_net() -> Network {
    Network::from_ops(
        "tiny-serve",
        32,
        32,
        3,
        &[conv(8, 3), maxpool(), conv(16, 3), maxpool(), conv(16, 1), conv(16, 3)],
    )
}

fn tiny_configs() -> Vec<MultiConfig> {
    vec![
        "1x1/NoCut".parse().unwrap(),
        "2x2/NoCut".parse().unwrap(),
        "2x2/2/2x2/4/1x1".parse().unwrap(), // k = 3 groups
        "4v4/2/4x4".parse().unwrap(),       // balanced-variant top group (the predicted floor)
    ]
}

/// Export the tiny-serve reference bundle once per test binary.
fn tiny_bundle() -> &'static str {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("mafat-test-serve-{}", std::process::id()));
        let net = tiny_net();
        write_reference_bundle(
            &dir,
            &[ExportSpec {
                net: &net,
                configs: tiny_configs(),
                emit_full: true,
            }],
        )
        .expect("export reference bundle");
        dir
    })
    .to_str()
    .unwrap()
}

/// A second, differently shaped net for the two-tenant tests (the
/// "mobilenet" stand-in): distinct outputs from `tiny_net`, tiny work.
fn tiny_net_b() -> Network {
    Network::from_ops(
        "tiny-serve-b",
        32,
        32,
        3,
        &[conv(4, 3), maxpool(), conv(8, 3), conv(8, 1)],
    )
}

fn tiny_bundle_b() -> &'static str {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("mafat-test-serve-b-{}", std::process::id()));
        let net = tiny_net_b();
        write_reference_bundle(
            &dir,
            &[ExportSpec {
                net: &net,
                configs: vec![
                    "1x1/NoCut".parse().unwrap(),
                    "2x2/NoCut".parse().unwrap(),
                    "2x2/2/1x1".parse().unwrap(),
                ],
                emit_full: true,
            }],
        )
        .expect("export second reference bundle");
        dir
    })
    .to_str()
    .unwrap()
}

fn start_server(config: &str, cfg: ServerConfig) -> Server {
    let dir = tiny_bundle().to_string();
    let config: MultiConfig = config.parse().unwrap();
    Server::start(
        move || Engine::load(&dir, config.clone()),
        "127.0.0.1:0",
        cfg,
    )
    .unwrap()
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .unwrap();
        Client {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    /// One request -> the raw response line (for byte-identity pins).
    fn raw_call(&mut self, req: &str) -> String {
        self.writer.write_all(req.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line
    }

    fn call(&mut self, req: &str) -> Json {
        let line = self.raw_call(req);
        Json::parse(&line).unwrap()
    }
}

#[test]
fn engine_load_failure_surfaces_from_start() {
    // No artifacts needed: a factory that fails must fail Server::start
    // itself (previously the worker died silently and queued clients hung
    // forever waiting on a response nobody would send). With a pool, any
    // failed worker fails startup.
    let result = Server::start(
        || anyhow::bail!("synthetic engine load failure"),
        "127.0.0.1:0",
        ServerConfig {
            workers: 3,
            ..ServerConfig::default()
        },
    );
    let err = match result {
        Ok(_) => panic!("start must surface the load error"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("engine failed to load"), "{err}");
    assert!(err.contains("synthetic engine load failure"), "{err}");
}

#[test]
fn serve_end_to_end() {
    let server = start_server("2x2/NoCut", ServerConfig::default());
    let addr = server.local_addr;
    let accept = std::thread::spawn(move || {
        let _ = server.run();
    });

    let mut c = Client::connect(addr);

    // Liveness.
    let pong = c.call(r#"{"cmd":"ping"}"#);
    assert!(pong.get("ok").unwrap().as_bool().unwrap());

    // Synthetic-image inference.
    let r = c.call(r#"{"cmd":"infer","id":"r1","seed":7}"#);
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    assert_eq!(r.str_at("id").unwrap(), "r1");
    let shape = r.get("shape").unwrap().as_arr().unwrap();
    assert_eq!(shape.len(), 3);
    assert!(r.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);

    // Same seed -> same checksum (deterministic serving).
    let r2 = c.call(r#"{"cmd":"infer","id":"r2","seed":7}"#);
    assert_eq!(
        r.get("checksum").unwrap().as_f64().unwrap(),
        r2.get("checksum").unwrap().as_f64().unwrap()
    );

    // Different seed -> different checksum.
    let r3 = c.call(r#"{"cmd":"infer","id":"r3","seed":8}"#);
    assert_ne!(
        r.get("checksum").unwrap().as_f64().unwrap(),
        r3.get("checksum").unwrap().as_f64().unwrap()
    );

    // Metrics after traffic.
    let m = c.call(r#"{"cmd":"metrics"}"#);
    assert!(m.get("ok").unwrap().as_bool().unwrap());
    let snapshot = m.str_at("metrics").unwrap();
    assert!(snapshot.contains("requests"), "{snapshot}");

    // Malformed request -> structured error, connection stays usable.
    let e = c.call(r#"{"cmd":"nonsense"}"#);
    assert!(!e.get("ok").unwrap().as_bool().unwrap());
    // Malformed image payload (strings instead of numbers) likewise.
    let e2 = c.call(r#"{"cmd":"infer","id":"bad-img","image":["x","y"]}"#);
    assert!(!e2.get("ok").unwrap().as_bool().unwrap());
    let pong2 = c.call(r#"{"cmd":"ping"}"#);
    assert!(pong2.get("ok").unwrap().as_bool().unwrap());

    // Failure injection: an image with the wrong element count must come
    // back as a structured per-request error, not kill the worker.
    let bad = c.call(r#"{"cmd":"infer","id":"bad","image":[1.0,2.0,3.0]}"#);
    assert!(!bad.get("ok").unwrap().as_bool().unwrap());
    assert!(bad.str_at("error").unwrap().contains("elems"), "{bad:?}");
    // The worker survives and keeps serving.
    let after = c.call(r#"{"cmd":"infer","id":"after-bad","seed":7}"#);
    assert!(after.get("ok").unwrap().as_bool().unwrap());

    // Parallel clients.
    let handles: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let r = c.call(&format!(r#"{{"cmd":"infer","id":"p{i}","seed":{i}}}"#));
                assert!(r.get("ok").unwrap().as_bool().unwrap());
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    drop(accept); // listener thread keeps running; process exit reaps it
}

/// Collect `output` arrays for a fixed set of seeds from a server.
fn outputs_for_seeds(addr: std::net::SocketAddr, seeds: &[u64]) -> Vec<Vec<f64>> {
    let mut c = Client::connect(addr);
    seeds
        .iter()
        .map(|seed| {
            let r = c.call(&format!(
                r#"{{"cmd":"infer","id":"s{seed}","seed":{seed},"return_output":true}}"#
            ));
            assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
            r.get("output")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect()
        })
        .collect()
}

#[test]
fn worker_pool_matches_single_worker_byte_for_byte() {
    // N workers must be an invisible optimization: the same requests get
    // byte-identical responses from a pool of 3 as from a single engine.
    let seeds: Vec<u64> = (0..6).collect();
    let single = start_server("2x2/2/2x2/4/1x1", ServerConfig::default());
    let addr1 = single.local_addr;
    std::thread::spawn(move || {
        let _ = single.run();
    });
    let pool = start_server(
        "2x2/2/2x2/4/1x1",
        ServerConfig {
            workers: 3,
            ..ServerConfig::default()
        },
    );
    let addr3 = pool.local_addr;
    std::thread::spawn(move || {
        let _ = pool.run();
    });

    let a = outputs_for_seeds(addr1, &seeds);
    let b = outputs_for_seeds(addr3, &seeds);
    assert_eq!(a, b, "pooled responses must equal single-worker responses");
}

#[test]
fn exec_team_of_two_matches_sequential_byte_for_byte() {
    // Intra-worker parallelism must be an invisible optimization too: a
    // two-thread tile team returns byte-identical responses to the
    // sequential executor, and the server publishes its team size plus
    // the selected SIMD kernel in the metrics snapshot.
    let seeds: Vec<u64> = (0..6).collect();
    let sequential = start_server(
        "2x2/2/2x2/4/1x1",
        ServerConfig {
            exec_threads: 1,
            ..ServerConfig::default()
        },
    );
    let addr1 = sequential.local_addr;
    std::thread::spawn(move || {
        let _ = sequential.run();
    });
    let teamed = start_server(
        "2x2/2/2x2/4/1x1",
        ServerConfig {
            exec_threads: 2,
            ..ServerConfig::default()
        },
    );
    let addr2 = teamed.local_addr;
    std::thread::spawn(move || {
        let _ = teamed.run();
    });

    let a = outputs_for_seeds(addr1, &seeds);
    let b = outputs_for_seeds(addr2, &seeds);
    assert_eq!(a, b, "teamed responses must equal sequential responses");

    let mut c = Client::connect(addr2);
    let m = c.call(r#"{"cmd":"metrics"}"#);
    let snapshot = m.str_at("metrics").unwrap();
    assert!(
        snapshot.contains("exec_threads 2"),
        "team size missing from metrics: {snapshot}"
    );
    assert!(
        snapshot.contains("simd_kernel{isa="),
        "selected kernel missing from metrics: {snapshot}"
    );
}

#[test]
fn worker_pool_serves_concurrent_load_and_aggregates_metrics() {
    let server = start_server(
        "2x2/NoCut",
        ServerConfig {
            workers: 3,
            max_batch: 2,
            ..ServerConfig::default()
        },
    );
    let addr = server.local_addr;
    std::thread::spawn(move || {
        let _ = server.run();
    });

    let n_clients = 4;
    let per_client = 5;
    let handles: Vec<_> = (0..n_clients)
        .map(|ci| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                for i in 0..per_client {
                    let r = c.call(&format!(
                        r#"{{"cmd":"infer","id":"c{ci}-{i}","seed":{}}}"#,
                        ci * 100 + i
                    ));
                    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
                    assert_eq!(r.str_at("id").unwrap(), format!("c{ci}-{i}"));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // All workers record into one shared registry.
    let mut c = Client::connect(addr);
    let m = c.call(r#"{"cmd":"metrics"}"#);
    let snapshot = m.str_at("metrics").unwrap();
    let requests: u64 = snapshot
        .lines()
        .find_map(|l| l.strip_prefix("requests "))
        .unwrap()
        .trim()
        .parse()
        .unwrap();
    assert_eq!(requests, (n_clients * per_client) as u64, "{snapshot}");
}

/// Start a governed server over the tiny bundle's full manifest ladder.
/// Returns the server and the governor handle (for state assertions).
fn start_governed(
    budget_bytes: u64,
    params: &PredictorParams,
    cfg: ServerConfig,
) -> (Server, Arc<MemoryGovernor>, MultiConfig) {
    let dir = tiny_bundle().to_string();
    let manifest = mafat::runtime::Manifest::load(std::path::Path::new(&dir)).unwrap();
    let mnet = manifest.sole_network().unwrap();
    let ladder = ladder_from_manifest(mnet, params).unwrap();
    let (picked, _) = auto_config_from_manifest(mnet, budget_bytes, params).unwrap();
    let start = ladder.position_of(&picked).unwrap();
    let workers = cfg.workers.max(1);
    let gcfg = GovernorConfig::default();
    let gov = MemoryGovernor::single(ladder, budget_bytes, start, cfg.max_batch, workers, gcfg);
    let governor = Arc::new(gov.unwrap());
    let factory_config = picked.clone();
    let server = Server::start_governed(
        move || Engine::load(&dir, factory_config.clone()),
        "127.0.0.1:0",
        cfg,
        Some(governor.clone()),
    )
    .unwrap();
    (server, governor, picked)
}

#[test]
fn governed_server_with_steady_budget_is_byte_identical_to_static_server() {
    // Acceptance pin: with a steady budget the governed server's responses
    // are byte-identical to the fixed-drain server's. "Steady" is made
    // deterministic by giving the budget ample headroom over the test
    // process's real RSS: the auto-pick then starts at the ladder's TOP
    // rung (the cheapest compiled config), where the only conceivable
    // transition — a step UP out of sustained headroom — has no rung to
    // land on, so the governor provably holds for the whole test.
    let Some(rss) = sample_rss_bytes() else {
        eprintln!("SKIP: no procfs RSS on this host");
        return;
    };
    // Budget such that rss < low_watermark * budget: pure headroom, and
    // the start rung (top of the ladder) has nowhere to step up to.
    let budget = (rss * 4).max(1 << 30);
    let params = PredictorParams::default();
    let (governed, governor, picked) = start_governed(
        budget,
        &params,
        ServerConfig {
            workers: 2,
            ..ServerConfig::default()
        },
    );
    // A huge budget picks the cheapest (largest-footprint) compiled
    // config — the ladder's top rung.
    let ladder = governor.ladder("default").unwrap();
    assert_eq!(
        ladder.position_of(&picked).unwrap(),
        ladder.len() - 1,
        "{picked} is not the top rung"
    );
    let gaddr = governed.local_addr;
    std::thread::spawn(move || {
        let _ = governed.run();
    });
    let fixed = start_server(
        &picked.to_string(),
        ServerConfig {
            workers: 2,
            ..ServerConfig::default()
        },
    );
    let faddr = fixed.local_addr;
    std::thread::spawn(move || {
        let _ = fixed.run();
    });

    let seeds: Vec<u64> = (0..8).collect();
    let a = outputs_for_seeds(gaddr, &seeds);
    let b = outputs_for_seeds(faddr, &seeds);
    assert_eq!(a, b, "governed responses must equal fixed-drain responses");
    // And the governor really never stepped.
    assert_eq!(governor.active_config("default").unwrap(), picked);

    // Observability: the governed wakes exported RSS + drain gauges.
    let mut c = Client::connect(gaddr);
    let m = c.call(r#"{"cmd":"metrics"}"#);
    let snapshot = m.str_at("metrics").unwrap();
    let field = |name: &str| -> u64 {
        snapshot
            .lines()
            .find_map(|l| l.strip_prefix(&format!("{name} ")))
            .unwrap_or_else(|| panic!("{name} missing from {snapshot}"))
            .trim()
            .parse()
            .unwrap()
    };
    assert!(field("rss_bytes") > MIB, "{snapshot}");
    assert!(field("governor_drain") >= 1, "{snapshot}");
    assert!(snapshot.contains("governor_swaps{dir=down} 0"), "{snapshot}");
    assert!(snapshot.contains("governor_swaps{dir=up} 0"), "{snapshot}");
}

#[test]
fn governed_server_under_tight_budget_steps_down_and_keeps_serving() {
    // Acceptance pin: a tight injected budget (every compiled config still
    // *predicts* as fitting under bias 0, but the live process RSS dwarfs
    // the watermarks) forces sustained pressure -> the governor walks the
    // ladder down to the smallest-footprint rung, workers hot-swap their
    // engines at batch boundaries, and every request keeps succeeding.
    let Some(rss) = sample_rss_bytes() else {
        eprintln!("SKIP: no procfs RSS on this host");
        return;
    };
    // Bias 0 makes the tiny net's predictions ~1-2 hundred KiB; a 2 MiB
    // budget fits them all (so the pick starts at the top rung) while the
    // multi-MB test process RSS sits far above the high watermark.
    let params = PredictorParams {
        bias_bytes: 0,
        ..PredictorParams::default()
    };
    let budget = 2 * MIB;
    assert!(rss > budget, "test process RSS must dwarf the budget");
    let (server, governor, picked) = start_governed(budget, &params, ServerConfig::default());
    let ladder = governor.ladder("default").unwrap();
    let ladder_len = ladder.len();
    assert!(ladder_len >= 2, "need rungs to step through");
    assert_eq!(ladder.position_of(&picked).unwrap(), ladder_len - 1);
    let floor = ladder.rungs()[0].config.clone();
    let addr = server.local_addr;
    std::thread::spawn(move || {
        let _ = server.run();
    });

    // Sequential requests: each is one worker wake. With hysteresis 3 and
    // a single worker, 3 wakes per step walk the whole ladder down well
    // within this many requests.
    let mut c = Client::connect(addr);
    let wakes = 3 * ladder_len + 4;
    let mut checksums = std::collections::HashMap::new();
    for i in 0..wakes {
        let seed = i % 2; // revisit seeds across swaps
        let r = c.call(&format!(r#"{{"cmd":"infer","id":"g{i}","seed":{seed}}}"#));
        assert!(r.get("ok").unwrap().as_bool().unwrap(), "wake {i}: {r:?}");
        // Different configs of one network produce the same map (§2.1.1),
        // so responses stay consistent ACROSS governor swaps too.
        let sum = r.get("checksum").unwrap().as_f64().unwrap();
        if let Some(prev) = checksums.insert(seed, sum) {
            assert_eq!(prev, sum, "wake {i}: checksum drifted across swaps");
        }
    }
    assert_eq!(
        governor.active_config("default").unwrap(),
        floor,
        "sustained pressure must land on the footprint floor"
    );
    let m = c.call(r#"{"cmd":"metrics"}"#);
    let snapshot = m.str_at("metrics").unwrap();
    let downs: u64 = snapshot
        .lines()
        .find_map(|l| l.strip_prefix("governor_swaps{dir=down} "))
        .unwrap()
        .trim()
        .parse()
        .unwrap();
    assert_eq!(downs, (ladder_len - 1) as u64, "one step per rung walked: {snapshot}");
    // Still serving after landing on the floor.
    let r = c.call(r#"{"cmd":"infer","id":"after","seed":9}"#);
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
}

#[test]
fn auto_pick_serves_variable_config_when_it_wins() {
    // A budget only the balanced-variant entry fits: the manifest
    // auto-pick must hand back the `TvT` config, and serving it returns
    // exactly what a directly loaded engine computes.
    let manifest = mafat::runtime::Manifest::load(std::path::Path::new(tiny_bundle())).unwrap();
    let mnet = manifest.sole_network().unwrap().clone();
    let net = mnet.network();
    let params = PredictorParams::default();
    let variable: MultiConfig = "4v4/2/4x4".parse().unwrap();
    let pv = predict_multi(&net, &variable, &params).unwrap().total_bytes;
    // Every *other* compiled entry must predict above the chosen limit.
    let others_floor = mnet
        .configs
        .iter()
        .filter(|e| e.config != variable)
        .map(|e| predict_multi(&net, &e.config, &params).unwrap().total_bytes)
        .min()
        .unwrap();
    assert!(
        pv < others_floor,
        "balanced entry must be the unique floor ({pv} vs {others_floor})"
    );
    let limit = (pv + others_floor) / 2;
    let (picked, bytes) = auto_config_from_manifest(&mnet, limit, &params).unwrap();
    assert_eq!(picked, variable, "auto-pick must select the variable entry");
    assert_eq!(bytes, pv);

    // Serve the pick and compare against a direct engine.
    let server = start_server(
        &picked.to_string(),
        ServerConfig {
            workers: 2,
            ..ServerConfig::default()
        },
    );
    let addr = server.local_addr;
    std::thread::spawn(move || {
        let _ = server.run();
    });
    let served = outputs_for_seeds(addr, &[7]);
    let mut direct = Engine::load(tiny_bundle(), picked).unwrap();
    let image = direct.synthetic_image(7);
    let (out, _) = direct.infer(&image).unwrap();
    let direct_out: Vec<f64> = out.data.iter().map(|&v| v as f64).collect();
    assert_eq!(served[0], direct_out);
}

/// Like [`outputs_for_seeds`], speaking protocol v1 at a named model.
fn outputs_for_seeds_v1(addr: std::net::SocketAddr, model: &str, seeds: &[u64]) -> Vec<Vec<f64>> {
    let mut c = Client::connect(addr);
    seeds
        .iter()
        .map(|seed| {
            let r = c.call(&format!(
                r#"{{"v":1,"cmd":"infer","model":"{model}","id":"s{seed}","seed":{seed},"return_output":true}}"#
            ));
            assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
            // v1 responses echo the protocol version and the model id.
            assert_eq!(r.get("v").unwrap().as_f64().unwrap(), 1.0, "{r:?}");
            assert_eq!(r.str_at("model").unwrap(), model, "{r:?}");
            r.get("output")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect()
        })
        .collect()
}

/// Auto-pick a config and build the footprint ladder for one bundle dir.
fn pick_and_ladder(
    dir: &str,
    budget: u64,
    params: &PredictorParams,
) -> (MultiConfig, mafat::search::ConfigLadder, usize) {
    let manifest = mafat::runtime::Manifest::load(std::path::Path::new(dir)).unwrap();
    let mnet = manifest.sole_network().unwrap();
    let ladder = ladder_from_manifest(mnet, params).unwrap();
    let (picked, _) = auto_config_from_manifest(mnet, budget, params).unwrap();
    let start = ladder.position_of(&picked).unwrap();
    (picked, ladder, start)
}

/// One governed server over both tiny bundles: model `default`
/// (interactive, the one legacy v0 clients hit) and model `mobile`
/// (batch), each auto-picked for the budget.
fn start_two_model(
    budget: u64,
    params: &PredictorParams,
    cfg: ServerConfig,
) -> (Server, Arc<MemoryGovernor>, MultiConfig, MultiConfig) {
    let dir_a = tiny_bundle().to_string();
    let dir_b = tiny_bundle_b().to_string();
    let (picked_a, ladder_a, start_a) = pick_and_ladder(&dir_a, budget, params);
    let (picked_b, ladder_b, start_b) = pick_and_ladder(&dir_b, budget, params);
    let workers = cfg.workers.max(1);
    let governor = Arc::new(
        MemoryGovernor::new(
            vec![
                TenantSpec {
                    name: "default".into(),
                    ladder: ladder_a,
                    start_rung: start_a,
                    qos: QosClass::Interactive,
                },
                TenantSpec {
                    name: "mobile".into(),
                    ladder: ladder_b,
                    start_rung: start_b,
                    qos: QosClass::Batch,
                },
            ],
            budget,
            cfg.max_batch,
            workers,
            GovernorConfig::default(),
        )
        .unwrap(),
    );
    let (fa, fb) = (picked_a.clone(), picked_b.clone());
    let server = Server::start_multi(
        vec![
            ModelSpec {
                name: "default".into(),
                qos: QosClass::Interactive,
                factory: Box::new(move || Engine::load(&dir_a, fa.clone())),
            },
            ModelSpec {
                name: "mobile".into(),
                qos: QosClass::Batch,
                factory: Box::new(move || Engine::load(&dir_b, fb.clone())),
            },
        ],
        "127.0.0.1:0",
        cfg,
        Some(governor.clone()),
    )
    .unwrap();
    (server, governor, picked_a, picked_b)
}

#[test]
fn two_models_one_budget() {
    let Some(rss) = sample_rss_bytes() else {
        eprintln!("SKIP: no procfs RSS on this host");
        return;
    };

    // ---- (a) steady budget: per-model responses are byte-identical to
    // two isolated single-model servers. Both tenants auto-pick their top
    // rung under the ample budget, so the governor provably holds (same
    // argument as the single-model steady test).
    let ample = (rss * 4).max(1 << 30);
    let params = PredictorParams::default();
    let (multi, governor, picked_a, picked_b) =
        start_two_model(ample, &params, ServerConfig::default());
    let maddr = multi.local_addr;
    std::thread::spawn(move || {
        let _ = multi.run();
    });
    let single_a = start_server(&picked_a.to_string(), ServerConfig::default());
    let saddr_a = single_a.local_addr;
    std::thread::spawn(move || {
        let _ = single_a.run();
    });
    let dir_b = tiny_bundle_b().to_string();
    let fb = picked_b.clone();
    let single_b = Server::start_multi(
        vec![ModelSpec {
            name: "mobile".into(),
            qos: QosClass::Batch,
            factory: Box::new(move || Engine::load(&dir_b, fb.clone())),
        }],
        "127.0.0.1:0",
        ServerConfig::default(),
        None,
    )
    .unwrap();
    let saddr_b = single_b.local_addr;
    std::thread::spawn(move || {
        let _ = single_b.run();
    });

    let seeds: Vec<u64> = (0..4).collect();
    // Legacy v0 clients (no v, no model) route to `default` unchanged.
    assert_eq!(
        outputs_for_seeds(maddr, &seeds),
        outputs_for_seeds(saddr_a, &seeds),
        "v0/default outputs must match the isolated server"
    );
    assert_eq!(
        outputs_for_seeds_v1(maddr, "mobile", &seeds),
        outputs_for_seeds_v1(saddr_b, "mobile", &seeds),
        "v1/mobile outputs must match the isolated server"
    );
    let mut cm = Client::connect(maddr);
    // Distinct engines really answer the two ids (not one routed twice).
    let ra = cm.call(r#"{"cmd":"infer","id":"xa","seed":9}"#);
    let rb = cm.call(r#"{"v":1,"cmd":"infer","model":"mobile","id":"xb","seed":9}"#);
    assert_ne!(
        ra.get("checksum").unwrap().as_f64().unwrap(),
        rb.get("checksum").unwrap().as_f64().unwrap()
    );
    assert_eq!(governor.active_config("default").unwrap(), picked_a);
    assert_eq!(governor.active_config("mobile").unwrap(), picked_b);

    // ---- (c) unknown model: its structured error comes back without
    // touching the queue, and the connection keeps serving.
    let e = cm.call(r#"{"v":1,"cmd":"infer","model":"nope","id":"u1","seed":1}"#);
    assert!(!e.get("ok").unwrap().as_bool().unwrap(), "{e:?}");
    assert_eq!(e.get("error").unwrap().str_at("code").unwrap(), "unknown_model");
    assert_eq!(e.str_at("id").unwrap(), "u1");
    let pong = cm.call(r#"{"v":1,"cmd":"ping"}"#);
    assert!(pong.get("ok").unwrap().as_bool().unwrap());

    // ---- (b) tight budget: sustained pressure steps only the
    // batch-class tenant's rung down; the interactive tenant's rung and
    // checksums hold. Bias 0 keeps every compiled config *predicting* as
    // fitting (so both auto-picks start at their top rungs) while the
    // test process RSS dwarfs the 2 MiB budget's watermarks.
    let params0 = PredictorParams {
        bias_bytes: 0,
        ..PredictorParams::default()
    };
    let budget = 2 * MIB;
    assert!(rss > budget, "test process RSS must dwarf the budget");
    let (tight, gov2, tpicked_a, _) = start_two_model(budget, &params0, ServerConfig::default());
    let lb = gov2.ladder("mobile").unwrap().len();
    assert!(lb >= 2, "batch tenant needs rungs to step through");
    let start_a = gov2.active_rung("default").unwrap();
    let taddr = tight.local_addr;
    std::thread::spawn(move || {
        let _ = tight.run();
    });

    let mut c = Client::connect(taddr);
    let mut checks_a = std::collections::HashMap::new();
    for i in 0..(3 * lb + 6) {
        let seed = i % 2;
        // Interleave the tenants; every drained batch is a governor wake.
        let ra = c.call(&format!(r#"{{"cmd":"infer","id":"a{i}","seed":{seed}}}"#));
        assert!(ra.get("ok").unwrap().as_bool().unwrap(), "wake {i}: {ra:?}");
        let sum = ra.get("checksum").unwrap().as_f64().unwrap();
        if let Some(prev) = checks_a.insert(seed, sum) {
            assert_eq!(prev, sum, "wake {i}: interactive checksum drifted");
        }
        let rb = c.call(&format!(
            r#"{{"v":1,"cmd":"infer","model":"mobile","id":"b{i}","seed":{seed}}}"#
        ));
        assert!(rb.get("ok").unwrap().as_bool().unwrap(), "wake {i}: {rb:?}");
    }
    assert_eq!(
        gov2.active_rung("mobile").unwrap(),
        0,
        "batch tenant must land on its floor"
    );
    assert_eq!(
        gov2.active_rung("default").unwrap(),
        start_a,
        "interactive rung must hold under pressure"
    );
    assert_eq!(gov2.active_config("default").unwrap(), tpicked_a);

    // Per-model metrics expose the asymmetry.
    let m = c.call(r#"{"cmd":"metrics"}"#);
    let snapshot = m.str_at("metrics").unwrap();
    let downs_b: u64 = snapshot
        .lines()
        .find_map(|l| l.strip_prefix("governor_swaps{model=mobile,dir=down} "))
        .unwrap_or_else(|| panic!("missing mobile swaps in {snapshot}"))
        .trim()
        .parse()
        .unwrap();
    assert_eq!(downs_b, (lb - 1) as u64, "one step per rung walked: {snapshot}");
    assert!(
        snapshot.contains("governor_swaps{model=default,dir=down} 0"),
        "{snapshot}"
    );
}

#[test]
fn sustained_overload_backpressure_isolates_tenants() {
    // Sustained-overload pin: one tenant flooded past its bounded queue
    // gets structured `queue_full` errors — and ONLY that tenant pays.
    // The other tenant's every request keeps succeeding with unchanged
    // checksums, because queues are bounded per model and the pop order
    // serves the interactive class first. The `after_batch` hook holds
    // each flooded batch in flight a little, so the depth-2 queue
    // overflows deterministically under 6 closed-loop flooders.
    use std::sync::atomic::{AtomicBool, Ordering};
    let dir_a = tiny_bundle().to_string();
    let dir_b = tiny_bundle_b().to_string();
    let ca: MultiConfig = "2x2/NoCut".parse().unwrap();
    let cb: MultiConfig = "2x2/NoCut".parse().unwrap();
    let hooks = ServeHooks {
        rss_sampler: None,
        after_batch: Some(Arc::new(|model: &str, _len: usize| {
            if model == "mobile" {
                std::thread::sleep(Duration::from_millis(25));
            }
        })),
    };
    let server = Server::start_multi_hooked(
        vec![
            ModelSpec {
                name: "default".into(),
                qos: QosClass::Interactive,
                factory: Box::new(move || Engine::load(&dir_a, ca.clone())),
            },
            ModelSpec {
                name: "mobile".into(),
                qos: QosClass::Batch,
                factory: Box::new(move || Engine::load(&dir_b, cb.clone())),
            },
        ],
        "127.0.0.1:0",
        ServerConfig {
            workers: 1,
            queue_depth: 2,
            ..ServerConfig::default()
        },
        None,
        hooks,
    )
    .unwrap();
    let addr = server.local_addr;
    std::thread::spawn(move || {
        let _ = server.run();
    });

    // Pre-flood baseline checksums for the protected tenant.
    let mut c = Client::connect(addr);
    let baseline: Vec<f64> = (0..2u64)
        .map(|seed| {
            let r = c.call(&format!(r#"{{"cmd":"infer","id":"pre{seed}","seed":{seed}}}"#));
            assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
            r.get("checksum").unwrap().as_f64().unwrap()
        })
        .collect();

    let stop = Arc::new(AtomicBool::new(false));
    let flooders: Vec<_> = (0..6)
        .map(|t| {
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let (mut ok, mut rejected, mut other) = (0u64, 0u64, 0u64);
                while !stop.load(Ordering::Relaxed) {
                    let r = c.call(&format!(
                        r#"{{"v":1,"cmd":"infer","model":"mobile","id":"f{t}","seed":{t}}}"#
                    ));
                    if r.get("ok").unwrap().as_bool().unwrap() {
                        ok += 1;
                    } else if r.get("error").unwrap().str_at("code").unwrap() == "queue_full" {
                        rejected += 1;
                    } else {
                        other += 1;
                    }
                }
                (ok, rejected, other)
            })
        })
        .collect();

    // Let the flood saturate the mobile queue, then keep using the
    // interactive tenant straight through it.
    std::thread::sleep(Duration::from_millis(150));
    for i in 0..20u64 {
        let seed = i % 2;
        let r = c.call(&format!(r#"{{"cmd":"infer","id":"i{i}","seed":{seed}}}"#));
        assert!(
            r.get("ok").unwrap().as_bool().unwrap(),
            "interactive request {i} failed mid-flood: {r:?}"
        );
        assert_eq!(
            r.get("checksum").unwrap().as_f64().unwrap(),
            baseline[seed as usize],
            "interactive checksum drifted mid-flood (request {i})"
        );
    }
    stop.store(true, Ordering::Relaxed);
    let (mut ok, mut rejected, mut other) = (0u64, 0u64, 0u64);
    for f in flooders {
        let (o, r, x) = f.join().unwrap();
        ok += o;
        rejected += r;
        other += x;
    }
    assert!(rejected > 0, "flood never overflowed the bounded queue (ok {ok})");
    assert!(ok > 0, "backpressure must shed load, not starve the tenant");
    assert_eq!(other, 0, "flooded tenant saw non-queue_full errors");
}

#[test]
fn injected_rss_sampler_steps_the_governor_without_real_pressure() {
    // The ServeHooks::rss_sampler seam: an injected memory signal drives
    // the governor deterministically on any host — down the whole ladder
    // under synthetic pressure, back up under synthetic headroom — while
    // the process's real RSS never changes. This is the seam the bench
    // scenarios build their accounted-footprint signal on.
    use std::sync::atomic::{AtomicU64, Ordering};
    let params = PredictorParams {
        bias_bytes: 0,
        ..PredictorParams::default()
    };
    let budget = 100 * MIB; // watermarks at 85 / 60 MiB
    let dir = tiny_bundle().to_string();
    let manifest = mafat::runtime::Manifest::load(std::path::Path::new(&dir)).unwrap();
    let mnet = manifest.sole_network().unwrap();
    let ladder = ladder_from_manifest(mnet, &params).unwrap();
    let len = ladder.len();
    assert!(len >= 2, "need rungs to step through");
    let top = len - 1;
    let start_config = ladder.rungs()[top].config.clone();
    let governor = Arc::new(
        MemoryGovernor::single(
            ladder,
            budget,
            top,
            ServerConfig::default().max_batch,
            1,
            GovernorConfig::default(),
        )
        .unwrap(),
    );
    let injected = Arc::new(AtomicU64::new(10 * MIB)); // well under the low watermark
    let sampler_cell = injected.clone();
    let hooks = ServeHooks {
        rss_sampler: Some(Arc::new(move || Some(sampler_cell.load(Ordering::Relaxed)))),
        after_batch: None,
    };
    let server = Server::start_multi_hooked(
        vec![ModelSpec {
            name: "default".into(),
            qos: QosClass::Interactive,
            factory: Box::new(move || Engine::load(&dir, start_config.clone())),
        }],
        "127.0.0.1:0",
        ServerConfig::default(),
        Some(governor.clone()),
        hooks,
    )
    .unwrap();
    let addr = server.local_addr;
    std::thread::spawn(move || {
        let _ = server.run();
    });

    let mut c = Client::connect(addr);
    let wake = |c: &mut Client, tag: &str, n: usize| {
        for i in 0..n {
            let r = c.call(&format!(r#"{{"cmd":"infer","id":"{tag}{i}","seed":{}}}"#, i % 2));
            assert!(r.get("ok").unwrap().as_bool().unwrap(), "{tag}{i}: {r:?}");
        }
    };
    // Low signal: the governor holds at the top rung (headroom, but no
    // rung above to step to).
    wake(&mut c, "hold", 8);
    assert_eq!(governor.active_rung("default").unwrap(), top);
    // Synthetic pressure (no real allocation anywhere): walk the whole
    // ladder down, one step per hysteresis streak.
    injected.store(95 * MIB, Ordering::Relaxed);
    wake(&mut c, "down", 3 * len + 4);
    assert_eq!(
        governor.active_rung("default").unwrap(),
        0,
        "injected pressure must walk the ladder to the floor"
    );
    // Synthetic headroom: climb all the way back.
    injected.store(10 * MIB, Ordering::Relaxed);
    wake(&mut c, "up", 3 * len + 4);
    assert_eq!(
        governor.active_rung("default").unwrap(),
        top,
        "injected headroom must walk the ladder back to the top"
    );
}
