//! Serving-loop integration: boot the coordinator on an ephemeral port and
//! speak the JSON-lines protocol over real TCP.

use mafat::coordinator::{Server, ServerConfig};
use mafat::engine::Engine;
use mafat::jsonlite::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::time::Duration;

fn artifacts_ok() -> bool {
    let ok = Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing - run `make artifacts`");
    }
    ok
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .unwrap();
        Client {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn call(&mut self, req: &str) -> Json {
        self.writer.write_all(req.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Json::parse(&line).unwrap()
    }
}

#[test]
fn engine_load_failure_surfaces_from_start() {
    // No artifacts needed: a factory that fails must fail Server::start
    // itself (previously the worker died silently and queued clients hung
    // forever waiting on a response nobody would send).
    let result = Server::start(
        || anyhow::bail!("synthetic engine load failure"),
        "127.0.0.1:0",
        ServerConfig::default(),
    );
    let err = match result {
        Ok(_) => panic!("start must surface the load error"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("engine failed to load"), "{err}");
    assert!(err.contains("synthetic engine load failure"), "{err}");
}

#[test]
fn serve_end_to_end() {
    if !artifacts_ok() {
        return;
    }
    let server = Server::start(
        || Engine::load("artifacts", "2x2/NoCut".parse().unwrap()),
        "127.0.0.1:0",
        ServerConfig::default(),
    )
    .unwrap();
    let addr = server.local_addr;
    let accept = std::thread::spawn(move || {
        let _ = server.run();
    });

    let mut c = Client::connect(addr);

    // Liveness.
    let pong = c.call(r#"{"cmd":"ping"}"#);
    assert!(pong.get("ok").unwrap().as_bool().unwrap());

    // Synthetic-image inference (engine may still be compiling: the queue
    // holds the request until the worker is ready).
    let r = c.call(r#"{"cmd":"infer","id":"r1","seed":7}"#);
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    assert_eq!(r.str_at("id").unwrap(), "r1");
    let shape = r.get("shape").unwrap().as_arr().unwrap();
    assert_eq!(shape.len(), 3);
    assert!(r.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);

    // Same seed -> same checksum (deterministic serving).
    let r2 = c.call(r#"{"cmd":"infer","id":"r2","seed":7}"#);
    assert_eq!(
        r.get("checksum").unwrap().as_f64().unwrap(),
        r2.get("checksum").unwrap().as_f64().unwrap()
    );

    // Different seed -> different checksum.
    let r3 = c.call(r#"{"cmd":"infer","id":"r3","seed":8}"#);
    assert_ne!(
        r.get("checksum").unwrap().as_f64().unwrap(),
        r3.get("checksum").unwrap().as_f64().unwrap()
    );

    // Metrics after traffic.
    let m = c.call(r#"{"cmd":"metrics"}"#);
    assert!(m.get("ok").unwrap().as_bool().unwrap());
    let snapshot = m.str_at("metrics").unwrap();
    assert!(snapshot.contains("requests"), "{snapshot}");

    // Malformed request -> structured error, connection stays usable.
    let e = c.call(r#"{"cmd":"nonsense"}"#);
    assert!(!e.get("ok").unwrap().as_bool().unwrap());
    let pong2 = c.call(r#"{"cmd":"ping"}"#);
    assert!(pong2.get("ok").unwrap().as_bool().unwrap());

    // Failure injection: an image with the wrong element count must come
    // back as a structured per-request error, not kill the worker.
    let bad = c.call(r#"{"cmd":"infer","id":"bad","image":[1.0,2.0,3.0]}"#);
    assert!(!bad.get("ok").unwrap().as_bool().unwrap());
    assert!(bad
        .str_at("error")
        .unwrap()
        .contains("elems"), "{bad:?}");
    // The worker survives and keeps serving.
    let after = c.call(r#"{"cmd":"infer","id":"after-bad","seed":7}"#);
    assert!(after.get("ok").unwrap().as_bool().unwrap());

    // Parallel clients.
    let handles: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let r = c.call(&format!(r#"{{"cmd":"infer","id":"p{i}","seed":{i}}}"#));
                assert!(r.get("ok").unwrap().as_bool().unwrap());
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    drop(accept); // listener thread keeps running; process exit reaps it
}
