//! CLI smoke tests: drive the actual `mafat` binary end to end (argument
//! parsing, subcommand wiring, output shape) for everything that does not
//! need artifacts.

use std::process::Command;

fn mafat(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_mafat"))
        .args(args)
        .output()
        .expect("spawn mafat");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn help_lists_commands() {
    let (ok, stdout, _) = mafat(&["help"]);
    assert!(ok);
    for cmd in ["table-2-1", "fig-4-3", "predict", "search", "simulate", "run", "serve"] {
        assert!(stdout.contains(cmd), "usage missing {cmd}");
    }
}

#[test]
fn unknown_command_fails_cleanly() {
    let (ok, _, stderr) = mafat(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"), "{stderr}");
}

#[test]
fn table_2_1_prints_all_layers() {
    let (ok, stdout, _) = mafat(&["table-2-1"]);
    assert!(ok);
    assert!(stdout.contains("608x608x3"));
    assert!(stdout.contains("38x38x512"));
}

#[test]
fn predict_with_swap_estimate() {
    let (ok, stdout, _) = mafat(&["predict", "--config", "5x5/8/2x2", "--limit-mb", "16"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("predicted max memory"));
    assert!(stdout.contains("estimated swap-in"));
}

#[test]
fn predict_multi_group() {
    let (ok, stdout, _) = mafat(&["predict", "--config", "4x4/4/3x3/12/1x1"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("4x4/4/3x3/12/1x1"));
}

#[test]
fn search_paper_and_extension() {
    let (ok, stdout, _) = mafat(&["search", "--limit-mb", "64"]);
    assert!(ok);
    assert!(stdout.contains("predicted"));
    let (ok2, stdout2, _) = mafat(&[
        "search", "--limit-mb", "48", "--max-groups", "3", "--max-tiling", "6",
    ]);
    assert!(ok2);
    // The 3-group search must find something below the 2-group 55.2 MB floor.
    assert!(!stdout2.contains("FALLBACK"), "{stdout2}");
}

#[test]
fn frontier_prints_pareto_points_and_pick() {
    let (ok, stdout, _) = mafat(&["frontier", "--max-groups", "3", "--limit-mb", "96"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("Pareto frontier"), "{stdout}");
    // The generous end of the frontier is always the untiled config.
    assert!(stdout.contains("1x1/NoCut"), "{stdout}");
    assert!(stdout.contains("pick for 96 MB"), "{stdout}");
    // Memory column is sorted ascending; at least a few points exist.
    assert!(stdout.lines().count() >= 5, "{stdout}");
}

#[test]
fn frontier_json_is_machine_readable() {
    let (ok, stdout, _) = mafat(&["frontier", "--max-groups", "3", "--limit-mb", "96", "--json"]);
    assert!(ok, "{stdout}");
    let j = mafat::jsonlite::Json::parse(&stdout).unwrap();
    let points = j.get("points").unwrap().as_arr().unwrap();
    assert!(points.len() >= 3, "only {} points", points.len());
    // Every point carries its per-group variant + boundaries.
    for p in points {
        for g in p.get("groups").unwrap().as_arr().unwrap() {
            assert!(matches!(g.str_at("variant").unwrap(), "even" | "balanced"));
            assert!(g.get("xs").unwrap().as_arr().unwrap().len() >= 2);
        }
    }
    let pick = j.get("pick").unwrap();
    assert!(pick.get("fits").unwrap().as_bool().unwrap());
}

#[test]
fn frontier_swap_axis_picks_below_the_floor() {
    // 32 MB is below the YOLOv2 no-swap floor: without --swap-axis the
    // frontier reports nothing fits; with it, it returns the minimal
    // predicted-stall configuration.
    let (ok, stdout, _) = mafat(&["frontier", "--limit-mb", "32"]);
    assert!(ok);
    assert!(stdout.contains("nothing fits"), "{stdout}");
    let (ok, stdout, _) = mafat(&[
        "frontier", "--variable", "--swap-axis", "--limit-mb", "32", "--json",
    ]);
    assert!(ok, "{stdout}");
    let j = mafat::jsonlite::Json::parse(&stdout).unwrap();
    let pick = j.get("pick").unwrap();
    assert!(!pick.get("fits").unwrap().as_bool().unwrap());
    assert!(pick.get("swap_stall_s").unwrap().as_f64().unwrap() >= 0.0);
    // The variable frontier reaches below the even floor: some point uses
    // balanced (TvT) tilings.
    let points = j.get("points").unwrap().as_arr().unwrap();
    assert!(
        points.iter().any(|p| p.str_at("config").unwrap().contains('v')),
        "{stdout}"
    );
}

#[test]
fn search_and_frontier_agree_on_variable_win_at_46mb() {
    // Pinned acceptance scenario: 46 MB sits below the even-grid floor
    // (~46.4 MB) but above the variable floor (~45.3 MB).
    let (ok, stdout, _) = mafat(&["frontier", "--max-groups", "2", "--limit-mb", "46"]);
    assert!(ok);
    assert!(stdout.contains("nothing fits"), "{stdout}");
    let (ok, stdout, _) = mafat(&[
        "frontier", "--max-groups", "2", "--variable", "--limit-mb", "46",
    ]);
    assert!(ok);
    assert!(stdout.contains("pick for 46 MB: 5v5/12/3v3"), "{stdout}");
}

#[test]
fn simulate_reports_breakdown() {
    let (ok, stdout, _) = mafat(&["simulate", "--config", "3x3/8/2x2", "--limit-mb", "48"]);
    assert!(ok);
    assert!(stdout.contains("latency"));
    assert!(stdout.contains("peak RSS"));
}

#[test]
fn simulate_rejects_bad_config() {
    let (ok, _, stderr) = mafat(&["simulate", "--config", "3x2/8/2x2"]);
    assert!(!ok);
    assert!(stderr.contains("square"), "{stderr}");
}

#[test]
fn simulate_rejects_zero_limit() {
    // Regression: a zero memory limit used to reach the page simulator
    // and loop instead of erroring.
    let (ok, _, stderr) = mafat(&["simulate", "--config", "3x3/8/2x2", "--limit-mb", "0"]);
    assert!(!ok);
    assert!(stderr.contains("must be > 0"), "{stderr}");
}

#[test]
fn custom_cfg_file_flows_through() {
    let dir = std::env::temp_dir().join("mafat_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("small.cfg");
    std::fs::write(
        &path,
        "[net]\nwidth=64\nheight=64\nchannels=3\n\
         [convolutional]\nfilters=16\nsize=3\nstride=1\npad=1\n\
         [maxpool]\nsize=2\nstride=2\n\
         [convolutional]\nfilters=32\nsize=3\nstride=1\npad=1\n\
         [maxpool]\nsize=2\nstride=2\n",
    )
    .unwrap();
    let (ok, stdout, stderr) = mafat(&[
        "predict",
        "--config",
        "2x2/NoCut",
        "--cfg",
        path.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("predicted max memory"), "{stdout}");
}

#[test]
fn export_geometry_to_stdout_parses() {
    let (ok, stdout, _) = mafat(&["export-geometry"]);
    assert!(ok);
    let j = mafat::jsonlite::Json::parse(&stdout).unwrap();
    assert!(j.get("networks").unwrap().as_arr().unwrap().len() == 1);
}

#[test]
fn export_bundle_writes_a_loadable_manifest() {
    let dir = std::env::temp_dir().join(format!("mafat_cli_bundle_{}", std::process::id()));
    let (ok, _, stderr) = mafat(&["export-bundle", "--out", dir.to_str().unwrap()]);
    assert!(ok, "{stderr}");
    let manifest = mafat::runtime::Manifest::load(&dir).unwrap();
    let mnet = manifest.sole_network().unwrap();
    assert_eq!(mnet.backend, mafat::runtime::BackendKind::Reference);
    assert!(mnet
        .configs
        .iter()
        .any(|c| c.config.to_string() == "5v5/12/3v3"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_and_serve_reject_malformed_tvt_configs_cleanly() {
    // Regression: malformed `TvT` strings must produce a clear parse error
    // (nonzero exit + message), never a panic, on both subcommands —
    // before any artifacts are touched.
    for bad in ["3v2/8/2x2", "5x5/8", "0v0/NoCut", "5x5//2x2"] {
        for cmd in ["run", "serve"] {
            let (ok, _, stderr) = mafat(&[cmd, "--config", bad]);
            assert!(!ok, "{cmd} --config {bad} must fail");
            assert!(
                stderr.contains("invalid --config"),
                "{cmd} --config {bad}: {stderr}"
            );
            assert!(
                !stderr.contains("panicked"),
                "{cmd} --config {bad} panicked: {stderr}"
            );
        }
    }
}

#[test]
fn run_accepts_unified_bundle_flag_and_warns_on_artifacts() {
    let dir = std::env::temp_dir().join(format!("mafat_cli_bundleflag_{}", std::process::id()));
    let net = mafat::network::yolov2::yolov2_16_scaled(48);
    mafat::runtime::export::write_reference_bundle(
        &dir,
        &[mafat::runtime::export::ExportSpec {
            net: &net,
            configs: vec!["2x2/NoCut".parse().unwrap()],
            emit_full: true,
        }],
    )
    .unwrap();
    // The unified spelling: --bundle DIR, no deprecation chatter.
    let (ok, stdout, stderr) =
        mafat(&["run", "--bundle", dir.to_str().unwrap(), "--config", "2x2/NoCut"]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("config 2x2/NoCut"), "{stdout}");
    assert!(!stderr.contains("deprecated"), "{stderr}");
    // The old flag still works but warns.
    let (ok, _, stderr) =
        mafat(&["run", "--artifacts", dir.to_str().unwrap(), "--config", "2x2/NoCut"]);
    assert!(ok, "{stderr}");
    assert!(stderr.contains("--artifacts is deprecated"), "{stderr}");
    // Mixing both is an error.
    let (ok, _, stderr) = mafat(&[
        "run",
        "--bundle",
        dir.to_str().unwrap(),
        "--artifacts",
        dir.to_str().unwrap(),
        "--config",
        "2x2/NoCut",
    ]);
    assert!(!ok);
    assert!(stderr.contains("deprecated"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_executes_a_reference_bundle_end_to_end() {
    // The full CLI path on a geometry-only bundle: export, then run a
    // k-group config with oracle verification on the pure-Rust executor.
    // (A small scaled net keeps this fast in debug builds; CI smoke runs
    // the default 160x160 bundle in release.)
    let dir = std::env::temp_dir().join(format!("mafat_cli_run_{}", std::process::id()));
    let net = mafat::network::yolov2::yolov2_16_scaled(48);
    mafat::runtime::export::write_reference_bundle(
        &dir,
        &[mafat::runtime::export::ExportSpec {
            net: &net,
            configs: vec!["2x2/4/2x2/12/2x2".parse().unwrap()],
            emit_full: true,
        }],
    )
    .unwrap();
    let (ok, stdout, stderr) = mafat(&[
        "run",
        "--artifacts",
        dir.to_str().unwrap(),
        "--config",
        "2x2/4/2x2/12/2x2",
        "--verify",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("config 2x2/4/2x2/12/2x2"), "{stdout}");
    assert!(stdout.contains("max |err| = 0.000e0"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}
