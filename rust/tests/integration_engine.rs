//! End-to-end engine integration: real artifacts, real PJRT execution.
//!
//! These tests need `make artifacts` to have run (the `test` Makefile
//! target guarantees it); they skip with a loud message when artifacts are
//! missing so a bare `cargo test` still passes.

use mafat::engine::Engine;
use mafat::plan::MafatConfig;
use std::path::Path;

fn artifacts_dir() -> Option<&'static str> {
    if Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("SKIP: artifacts/manifest.json missing - run `make artifacts`");
        None
    }
}

fn configs() -> Vec<MafatConfig> {
    vec![
        "1x1/NoCut".parse().unwrap(),
        "2x2/NoCut".parse().unwrap(),
        "3x3/8/2x2".parse().unwrap(),
        "5x5/8/2x2".parse().unwrap(),
        "2x2/12/2x2".parse().unwrap(),
    ]
}

#[test]
fn every_compiled_config_verifies_against_untiled_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    for config in configs() {
        let mut engine = Engine::load(dir, config).unwrap();
        let image = engine.synthetic_image(7);
        let err = engine.verify(&image).unwrap();
        // Same kernels, same fp32 op order per output cell: tiling must be
        // numerically *identical*, not just close (paper §2.1.1).
        assert_eq!(err, 0.0, "{config}: max |err| = {err}");
    }
}

#[test]
fn inference_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(dir, "3x3/8/2x2".parse().unwrap()).unwrap();
    let image = engine.synthetic_image(99);
    let (a, _) = engine.infer(&image).unwrap();
    let (b, _) = engine.infer(&image).unwrap();
    assert_eq!(a.data, b.data);
}

#[test]
fn all_configs_agree_with_each_other() {
    // Different tilings/cuts of the same network on the same image must
    // produce the same final map.
    let Some(dir) = artifacts_dir() else { return };
    let mut outputs = Vec::new();
    for config in configs() {
        let mut engine = Engine::load(dir, config).unwrap();
        let image = engine.synthetic_image(3);
        let (out, stats) = engine.infer(&image).unwrap();
        assert!(stats.tasks > 0);
        outputs.push((config, out.data));
    }
    let (c0, first) = &outputs[0];
    for (c, data) in &outputs[1..] {
        assert_eq!(first, data, "{c0} vs {c} disagree");
    }
}

#[test]
fn different_images_differ() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(dir, "2x2/NoCut".parse().unwrap()).unwrap();
    let (a, _) = engine.infer(&engine.synthetic_image(1)).unwrap();
    let (b, _) = engine.infer(&engine.synthetic_image(2)).unwrap();
    assert_ne!(a.data, b.data);
}

#[test]
fn wrong_image_size_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(dir, "2x2/NoCut".parse().unwrap()).unwrap();
    assert!(engine.infer(&[0.0; 10]).is_err());
}

#[test]
fn missing_config_is_a_clear_error() {
    let Some(dir) = artifacts_dir() else { return };
    let err = Engine::load(dir, "4x4/4/3x3".parse::<MafatConfig>().unwrap())
        .err()
        .expect("should fail")
        .to_string();
    assert!(err.contains("not in manifest") || err.contains("4x4/4/3x3"), "{err}");
}

#[test]
fn output_shape_matches_network() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(dir, "1x1/NoCut".parse().unwrap()).unwrap();
    // 160 input, 4 pools -> 10x10; final conv stack ends at 256 channels.
    assert_eq!(engine.output_shape(), (10, 10, 256));
}

#[test]
fn task_metrics_accumulate() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(dir, "5x5/8/2x2".parse().unwrap()).unwrap();
    let image = engine.synthetic_image(5);
    let (_, stats) = engine.infer(&image).unwrap();
    assert_eq!(stats.tasks, 25 + 4);
    assert_eq!(engine.metrics.tasks_executed.get(), 29);
    assert!(engine.metrics.task_latency.percentile(0.5).is_some());
}
