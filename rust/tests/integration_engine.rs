//! End-to-end engine integration over *reference bundles*: geometry-only
//! artifacts exported by the tiler and executed by the pure-Rust reference
//! executor — so k-group and variable-tiling execution, oracle
//! verification, and the manifest boundary plumbing are all exercised on
//! every `cargo test`, with no XLA toolchain and no `make artifacts`.
//!
//! A PJRT bundle (when `make artifacts` has run) and the CI-exported
//! default bundle (`MAFAT_ARTIFACTS` env) are additionally covered by the
//! gated tests at the bottom.

use mafat::engine::{Engine, EngineShared};
use mafat::network::{LayerKind, Network};
use mafat::plan::MultiConfig;
use mafat::runtime::export::{write_reference_bundle, ExportSpec};
use mafat::runtime::reference;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

fn conv(filters: usize, size: usize) -> LayerKind {
    LayerKind::Conv {
        filters,
        size,
        stride: 1,
        pad: size / 2,
    }
}

/// The scaled-down YOLOv2-16 most reference tests run: 48x48 keeps a full
/// tiled + oracle pass well under a second in debug builds.
fn yolo48_configs() -> Vec<MultiConfig> {
    vec![
        "3x3/8/2x2".parse().unwrap(),        // paper 2-group shape
        "2x2/4/2x2/12/2x2".parse().unwrap(), // k = 3 groups
        "3v3/8/2x2".parse().unwrap(),        // variable (balanced) top group
        "4x4/4/2x2".parse().unwrap(),        // shallow 4x4 group: multi-tile classes
    ]
}

fn bundle_for(tag: &str, net: &Network, configs: Vec<MultiConfig>) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mafat-test-{tag}-{}", std::process::id()));
    write_reference_bundle(
        &dir,
        &[ExportSpec {
            net,
            configs,
            emit_full: true,
        }],
    )
    .expect("export reference bundle");
    dir
}

/// Export the yolo48 reference bundle once per test binary.
fn yolo48_bundle() -> &'static str {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        bundle_for(
            "engine48",
            &mafat::network::yolov2::yolov2_16_scaled(48),
            yolo48_configs(),
        )
    })
    .to_str()
    .unwrap()
}

#[test]
fn k_group_config_verifies_against_untiled_oracle() {
    let config: MultiConfig = "2x2/4/2x2/12/2x2".parse().unwrap();
    let mut engine = Engine::load(yolo48_bundle(), config.clone()).unwrap();
    assert_eq!(engine.config(), &config);
    let image = engine.synthetic_image(7);
    let err = engine.verify(&image).unwrap();
    // Same accumulation order per output cell: tiling must be numerically
    // *identical* to the untiled network, not just close (paper §2.1.1).
    assert_eq!(err, 0.0, "{config}: max |err| = {err}");
}

#[test]
fn variable_config_verifies_against_untiled_oracle() {
    let config: MultiConfig = "3v3/8/2x2".parse().unwrap();
    let mut engine = Engine::load(yolo48_bundle(), config.clone()).unwrap();
    let image = engine.synthetic_image(7);
    let err = engine.verify(&image).unwrap();
    assert_eq!(err, 0.0, "{config}: max |err| = {err}");
    let (_, stats) = engine.infer(&image).unwrap();
    assert_eq!(stats.tasks, 9 + 4);
}

#[test]
fn variable_search_winner_5v5_12_3v3_loads_runs_and_verifies() {
    // The exact configuration the variable search wins YOLOv2-16 with
    // (`5v5/12/3v3`, PR 2's 45.3 MB floor) — executed for real on a
    // channel-narrowed net with the YOLOv2-16 layer/pool structure (80x80
    // is the smallest input admitting a 5x5 grid under four pools; 1/8th
    // channels keep the debug-build verify fast — CI smoke runs the same
    // config on the true 160x160 default bundle in release).
    let maxpool = || LayerKind::MaxPool { size: 2, stride: 2 };
    #[rustfmt::skip]
    let ops = [
        conv(4, 3), maxpool(), conv(8, 3), maxpool(),
        conv(16, 3), conv(8, 1), conv(16, 3), maxpool(),
        conv(32, 3), conv(16, 1), conv(32, 3), maxpool(),
        conv(64, 3), conv(32, 1), conv(64, 3), conv(32, 1),
    ];
    let net = Network::from_ops("yolo-narrow-80", 80, 80, 3, &ops);
    let config: MultiConfig = "5v5/12/3v3".parse().unwrap();
    let dir = bundle_for("engine80", &net, vec![config.clone()]);
    let mut engine = Engine::load(&dir, config.clone()).unwrap();
    assert_eq!(engine.config(), &config);
    let image = engine.synthetic_image(7);
    let err = engine.verify(&image).unwrap();
    assert_eq!(err, 0.0, "{config}: max |err| = {err}");
    let (_, stats) = engine.infer(&image).unwrap();
    assert_eq!(stats.tasks, 25 + 9);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn all_compiled_configs_agree_with_each_other() {
    // Different cut counts, tilings, and variants of the same network on
    // the same image must produce the same final map.
    let mut outputs = Vec::new();
    for config in yolo48_configs() {
        let mut engine = Engine::load(yolo48_bundle(), config.clone()).unwrap();
        let image = engine.synthetic_image(3);
        let (out, stats) = engine.infer(&image).unwrap();
        assert!(stats.tasks > 0);
        outputs.push((config, out.data));
    }
    let (c0, first) = &outputs[0];
    for (c, data) in &outputs[1..] {
        assert_eq!(first, data, "{c0} vs {c} disagree");
    }
}

#[test]
fn genuinely_uneven_boundaries_execute_and_verify() {
    // A pool-free conv stack where the balanced-boundary search produces
    // truly uneven spans (border tiles wider than interior ones): the
    // manifest serializes them, the engine resolves tile rects *from the
    // serialized xs/ys*, and tiled output still matches the oracle
    // bit-exactly.
    let net = Network::from_ops("halo-net", 24, 24, 3, &[conv(8, 3), conv(8, 3), conv(8, 3)]);
    let config: MultiConfig = "3v3/NoCut".parse().unwrap();
    let dir = bundle_for("halo", &net, vec![config.clone()]);

    // The serialized boundaries are genuinely uneven.
    let manifest = mafat::runtime::Manifest::load(&dir).unwrap();
    let entry = &manifest.sole_network().unwrap().configs[0];
    let xs = entry.groups[0].xs.clone().expect("bounds serialized");
    let even: Vec<usize> = (0..=3).map(|k| k * 24 / 3).collect();
    assert_ne!(xs, even, "balancing must move the boundaries");

    let mut engine = Engine::load(&dir, config).unwrap();
    let image = engine.synthetic_image(5);
    let err = engine.verify(&image).unwrap();
    assert_eq!(err, 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batched_infer_is_byte_identical_to_sequential() {
    // Intra-worker batching (one executor call per tile class over the
    // gathered tiles of the whole image batch) must be invisible: for a
    // k-group AND a variable (balanced) config, infer_batch over several
    // images equals per-image infer byte for byte — including batch = 1.
    for config in ["2x2/4/2x2/12/2x2", "3v3/8/2x2"] {
        let mut engine = Engine::load(yolo48_bundle(), config.parse().unwrap()).unwrap();
        let images: Vec<Vec<f32>> = (0..3).map(|i| engine.synthetic_image(50 + i)).collect();
        let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
        let batched = engine.infer_batch(&refs).unwrap();
        assert_eq!(batched.len(), images.len());
        for (i, image) in images.iter().enumerate() {
            let (seq, stats) = engine.infer(image).unwrap();
            assert_eq!(batched[i].0.data, seq.data, "{config}: image {i} diverged");
            assert_eq!(batched[i].1.tasks, stats.tasks);
        }
        // Batch of one through the same path.
        let one = engine.infer_batch(&refs[..1]).unwrap();
        assert_eq!(one[0].0.data, batched[0].0.data, "{config}: batch=1 diverged");
    }
}

#[test]
fn class_batching_collapses_executor_calls() {
    // One inference issues one executor call per distinct tile class. On a
    // deeply fused group every tile position is its own class (each
    // corner/edge/center has a unique pad signature), so collapse needs a
    // grid with repeated interior positions: `4x4/4/2x2`'s shallow top
    // group runs 16 tasks in 9 classes (20 tasks vs 13 classes overall —
    // cross-checked by the numpy port).
    let mut engine = Engine::load(yolo48_bundle(), "4x4/4/2x2".parse().unwrap()).unwrap();
    let image = engine.synthetic_image(5);
    let (_, stats) = engine.infer(&image).unwrap();
    let calls = engine.metrics.exec_calls.get();
    let tasks = engine.metrics.tasks_executed.get();
    assert_eq!(stats.exec_calls as u64, calls);
    assert_eq!(tasks, 20);
    assert!(
        calls < tasks,
        "batching must issue fewer executor calls ({calls}) than tasks ({tasks})"
    );
    // Distinct classes (n_executables minus the untiled oracle) == calls.
    assert_eq!(calls as usize, engine.n_executables() - 1);
    let class_total: u64 = engine
        .metrics
        .class_tiles
        .snapshot()
        .iter()
        .map(|(_, n)| n)
        .sum();
    assert_eq!(class_total, tasks, "class counters must cover every task");
}

#[test]
fn reconfigure_reuses_packed_weights_and_matches_fresh_load() {
    // The load/plan split's two acceptance pins in one sequence:
    //
    // 1. Weights are packed EXACTLY once per bundle — the shared weight
    //    stage packs at `EngineShared::load`; building engines on it and
    //    hot-swapping configs packs zero more times. (The counter is
    //    thread-local, so concurrent tests loading their own engines
    //    cannot inflate this thread's count.)
    // 2. A reconfigured engine's output is byte-identical to a fresh
    //    `Engine::load` of the same configuration — for a k-group AND a
    //    variable (balanced) config.
    let packs_before = reference::pack_weights_calls();
    let shared = EngineShared::load(yolo48_bundle()).unwrap();
    assert_eq!(
        reference::pack_weights_calls() - packs_before,
        1,
        "weight stage must pack exactly once"
    );
    let packs_loaded = reference::pack_weights_calls();

    let start: MultiConfig = "3x3/8/2x2".parse().unwrap();
    let mut engine = Engine::with_shared(shared.clone(), start.clone()).unwrap();
    let mut sibling = Engine::with_shared(shared.clone(), start.clone()).unwrap();
    assert!(
        Arc::ptr_eq(engine.shared_state(), sibling.shared_state()),
        "pool engines must share one weight stage"
    );
    let image = engine.synthetic_image(41);
    let (before, _) = engine.infer(&image).unwrap();

    for target in ["2x2/4/2x2/12/2x2", "3v3/8/2x2"] {
        let config: MultiConfig = target.parse().unwrap();
        engine.reconfigure(&config).unwrap();
        assert_eq!(engine.config(), &config);
        let (swapped, _) = engine.infer(&image).unwrap();
        let mut fresh = Engine::load(yolo48_bundle(), config.clone()).unwrap();
        let (direct, _) = fresh.infer(&image).unwrap();
        assert_eq!(swapped.data, direct.data, "{target}: reconfigure diverged from a fresh load");
        // Different tilings of one network agree on the final map anyway
        // (the §2.1.1 equivalence) — so also pin against the first config.
        assert_eq!(swapped.data, before.data, "{target}");
    }
    // Swapping back works and still matches the original run bit for bit.
    engine.reconfigure(&start).unwrap();
    let (back, _) = engine.infer(&image).unwrap();
    assert_eq!(back.data, before.data);

    // The entire sequence — two engines, three reconfigures, one fresh
    // load per target — repacked only for the two fresh `Engine::load`s
    // (each runs its own weight stage); the shared stage never repacked.
    drop(sibling);
    assert_eq!(
        reference::pack_weights_calls() - packs_loaded,
        2,
        "reconfigure must never repack weights"
    );
}

#[test]
fn reconfigure_to_unknown_config_is_an_error_and_keeps_serving() {
    let start: MultiConfig = "3x3/8/2x2".parse().unwrap();
    let mut engine = Engine::load(yolo48_bundle(), start.clone()).unwrap();
    let image = engine.synthetic_image(43);
    let (before, _) = engine.infer(&image).unwrap();
    let err = engine
        .reconfigure(&"9x9/NoCut".parse::<MultiConfig>().unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("not in manifest"), "{err}");
    // The failed swap left the engine on its previous config, still good.
    assert_eq!(engine.config(), &start);
    let (after, _) = engine.infer(&image).unwrap();
    assert_eq!(before.data, after.data);
}

#[test]
fn inference_is_deterministic() {
    let mut engine = Engine::load(yolo48_bundle(), "3x3/8/2x2".parse().unwrap()).unwrap();
    let image = engine.synthetic_image(99);
    let (a, _) = engine.infer(&image).unwrap();
    let (b, _) = engine.infer(&image).unwrap();
    assert_eq!(a.data, b.data);
}

#[test]
fn different_images_differ() {
    let mut engine = Engine::load(yolo48_bundle(), "3x3/8/2x2".parse().unwrap()).unwrap();
    let (a, _) = engine.infer(&engine.synthetic_image(1)).unwrap();
    let (b, _) = engine.infer(&engine.synthetic_image(2)).unwrap();
    assert_ne!(a.data, b.data);
}

#[test]
fn wrong_image_size_rejected() {
    let mut engine = Engine::load(yolo48_bundle(), "3x3/8/2x2".parse().unwrap()).unwrap();
    assert!(engine.infer(&[0.0; 10]).is_err());
}

#[test]
fn missing_config_is_a_named_error() {
    // Asking for a config the bundle never compiled must fail with an
    // error naming the missing config and listing what *is* available.
    let err = Engine::load(yolo48_bundle(), "4x4/4/3x3".parse::<MultiConfig>().unwrap())
        .err()
        .expect("should fail")
        .to_string();
    assert!(err.contains("4x4/4/3x3"), "{err}");
    assert!(err.contains("not in manifest"), "{err}");
    assert!(err.contains("2x2/4/2x2/12/2x2"), "should list available configs: {err}");
}

#[test]
fn output_shape_matches_network() {
    let engine = Engine::load(yolo48_bundle(), "3x3/8/2x2".parse().unwrap()).unwrap();
    // 48 input, 4 pools -> 3x3; final conv stack ends at 256 channels.
    assert_eq!(engine.output_shape(), (3, 3, 256));
}

#[test]
fn task_metrics_accumulate() {
    let mut engine = Engine::load(yolo48_bundle(), "2x2/4/2x2/12/2x2".parse().unwrap()).unwrap();
    let image = engine.synthetic_image(5);
    let (_, stats) = engine.infer(&image).unwrap();
    assert_eq!(stats.tasks, 4 + 4 + 4);
    assert_eq!(engine.metrics.tasks_executed.get(), 12);
    assert!(engine.metrics.task_latency.percentile(0.5).is_some());
}

// ------------------------------------------------------------ gated bundles

/// The default exported bundle (CI smoke: `mafat export-bundle --out DIR`
/// then `MAFAT_ARTIFACTS=DIR`): every compiled config — k-group and
/// variable included — must verify against the oracle.
#[test]
fn default_bundle_from_env_verifies_every_config() {
    let Ok(dir) = std::env::var("MAFAT_ARTIFACTS") else {
        eprintln!("SKIP: MAFAT_ARTIFACTS unset - run `mafat export-bundle` and point it there");
        return;
    };
    let manifest = mafat::runtime::Manifest::load(std::path::Path::new(&dir)).unwrap();
    let configs: Vec<MultiConfig> = manifest
        .sole_network()
        .unwrap()
        .configs
        .iter()
        .map(|c| c.config.clone())
        .collect();
    assert!(configs.iter().any(|c| c.to_string() == "5v5/12/3v3"));
    for config in configs {
        let mut engine = Engine::load(&dir, config.clone()).unwrap();
        let image = engine.synthetic_image(7);
        let err = engine.verify(&image).unwrap();
        assert_eq!(err, 0.0, "{config}: max |err| = {err}");
    }
}

/// PJRT bundles from `make artifacts`, when present.
#[test]
fn pjrt_artifacts_verify_when_present() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing - run `make artifacts`");
        return;
    }
    for config in ["3x3/8/2x2", "5x5/8/2x2"] {
        let config: MultiConfig = config.parse().unwrap();
        let mut engine = Engine::load("artifacts", config.clone()).unwrap();
        let image = engine.synthetic_image(7);
        let err = engine.verify(&image).unwrap();
        assert_eq!(err, 0.0, "{config}: max |err| = {err}");
    }
}
