//! Cross-module integration below the PJRT layer (no artifacts needed):
//! geometry export <-> manifest schema <-> tiler, and simulator <->
//! predictor <-> search consistency over the whole manual space.

use mafat::jsonlite::Json;
use mafat::network::yolov2::{yolov2_16, yolov2_16_scaled};
use mafat::network::MIB;
use mafat::plan::{manual_search_space, plan_config, MafatConfig};
use mafat::predictor::{predict_mem, PredictorParams};
use mafat::runtime::export::default_export;
use mafat::runtime::Manifest;
use mafat::search::{exhaustive_by_latency, get_config};
use mafat::simulate::{simulate_config, SimOptions};

#[test]
fn manifest_on_disk_matches_tiler_when_present() {
    // If `make artifacts` ran, the real manifest must verify against a
    // fresh plan for every config it advertises.
    let Ok(m) = Manifest::load(std::path::Path::new("artifacts")) else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let net = m.sole_network().unwrap();
    assert_eq!(net.network().layers, yolov2_16_scaled(160).layers);
    for cfg in &net.configs {
        net.verify_geometry(&cfg.config).unwrap();
    }
}

#[test]
fn export_geometry_total_task_coverage() {
    // In the default export, every config's tasks exactly tile the final
    // output map of its bottom group.
    let j = default_export().unwrap();
    let net_json = &j.get("networks").unwrap().as_arr().unwrap()[0];
    let net = yolov2_16_scaled(160);
    for cfg in net_json.get("configs").unwrap().as_arr().unwrap() {
        let groups = cfg.get("groups").unwrap().as_arr().unwrap();
        for g in groups {
            let bottom = g.usize_at("bottom").unwrap();
            let (w, h, _) = net.out_shape(bottom);
            let total: usize = g
                .get("tasks")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|t| {
                    let r = t.get("out_rect").unwrap().as_arr().unwrap();
                    let (x0, y0, x1, y1) = (
                        r[0].as_usize().unwrap(),
                        r[1].as_usize().unwrap(),
                        r[2].as_usize().unwrap(),
                        r[3].as_usize().unwrap(),
                    );
                    (x1 - x0) * (y1 - y0)
                })
                .sum();
            assert_eq!(total, w * h);
        }
    }
}

#[test]
fn export_json_round_trips_through_parser() {
    let j = default_export().unwrap();
    assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
}

#[test]
fn algorithm_config_close_to_exhaustive_best() {
    // The paper's §4.4 claim on the simulated testbed: Algorithm 3's
    // configuration is within a few percent of the best configuration
    // found by exhaustive search, at every memory point.
    let net = yolov2_16();
    let opts = SimOptions::default();
    let params = PredictorParams::default();
    for mb in [96u64, 64, 48, 32, 16] {
        let o = SimOptions {
            limit_bytes: Some(mb * MIB),
            ..opts
        };
        let ranked = exhaustive_by_latency(&net, |c| {
            Ok(simulate_config(&net, c, &o)?.latency_s)
        })
        .unwrap();
        let (best_cfg, best_s) = ranked[0];
        let algo = get_config(&net, mb * MIB, &params).unwrap();
        let algo_s = simulate_config(&net, algo.config, &o).unwrap().latency_s;
        let gap = (algo_s - best_s) / best_s;
        assert!(
            gap < 0.12,
            "{mb} MB: algo {} ({algo_s:.1}s) vs best {best_cfg} ({best_s:.1}s) gap {:.0}%",
            algo.config,
            gap * 100.0
        );
    }
}

#[test]
fn predictor_ranks_like_simulator_footprints() {
    // Spearman-style sanity: across the manual space, configs the
    // predictor calls smaller must not have systematically *larger*
    // simulated footprints (within one bucket of noise).
    let net = yolov2_16();
    let opts = SimOptions::default();
    let mut points: Vec<(f64, f64)> = Vec::new();
    for config in manual_search_space(&net) {
        let p = predict_mem(&net, config, &PredictorParams::default()).unwrap();
        let plan = plan_config(&net, config).unwrap();
        let steps = mafat_trace_for(&net, &plan, &opts);
        // Peak RSS under no limit = what the process actually needs.
        let r = mafat::simulate::run_trace(&steps, None, &opts.cost).unwrap();
        points.push((p.total_mb(), r.peak_rss_mb()));
    }
    // Rank correlation (concordant vs discordant pairs).
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..points.len() {
        for j in i + 1..points.len() {
            let d = (points[i].0 - points[j].0) * (points[i].1 - points[j].1);
            if d > 0.0 {
                concordant += 1;
            } else if d < 0.0 {
                discordant += 1;
            }
        }
    }
    let tau = (concordant - discordant) as f64 / (concordant + discordant).max(1) as f64;
    assert!(
        tau > 0.6,
        "predictor/simulator rank correlation too weak: tau = {tau:.2}"
    );
}

fn mafat_trace_for(
    net: &mafat::network::Network,
    plan: &mafat::plan::Plan,
    opts: &SimOptions,
) -> Vec<mafat::simulate::Step> {
    mafat::simulate::mafat_trace(net, plan, opts)
}

#[test]
fn cfg_file_round_trip_through_cli_surface() {
    // A cfg written to disk parses to the same network the built-in uses.
    let dir = std::env::temp_dir().join("mafat_test_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("yolov2_16.cfg");
    std::fs::write(&path, mafat::network::cfg::YOLOV2_16_CFG).unwrap();
    let net = mafat::network::cfg::load_cfg(&path).unwrap();
    assert_eq!(net.layers, yolov2_16().layers);
    // And the full pipeline below PJRT runs on it.
    let r = simulate_config(&net, MafatConfig::with_cut(5, 8, 2), &SimOptions::default()).unwrap();
    assert!(r.latency_s > 0.0);
}
