//! Property-based tests over randomized networks and configurations.
//!
//! The offline environment has no proptest; `Cases` below is a small
//! deterministic driver over the crate's SplitMix64 — every failure prints
//! the seed, and re-running with that seed reproduces the case exactly.

use mafat::coordinator::{derive_drain, TokenBucket};
use mafat::data::SplitMix64;
use mafat::engine::{gen_network_weights, FeatureMap, WEIGHT_SEED};
use mafat::ftp::{balance_spans, down_extent, plan_group, plan_group_from_bounds, Rect};
use mafat::network::{LayerKind, Network, MIB};
use mafat::plan::{plan_config, MafatConfig};
use mafat::predictor::{predict_mem, PredictorParams};
use mafat::reuse::{reuse_analysis, schedule_order};
use mafat::runtime::{parallel, reference};
use mafat::search::get_config;

const CASES: u64 = 60;

/// Run `f` over `n` deterministic cases, reporting the failing seed.
fn cases(n: u64, f: impl Fn(&mut SplitMix64)) {
    for seed in 0..n {
        let mut rng = SplitMix64::new(0xC0FFEE ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// A random conv/maxpool prefix with valid (even, large-enough) dims.
/// All sizes are knobs so geometry props can range wide while *executing*
/// props stay debug-build fast ([`random_small_network`]).
#[allow(clippy::too_many_arguments)]
fn random_network_sized(
    rng: &mut SplitMix64,
    layer_spread: usize,
    max_pools: usize,
    filter_shift_base: usize,
    filter_shift_spread: usize,
    wh_base: usize,
    wh_spread: usize,
) -> Network {
    let mut ops = Vec::new();
    let n_layers = 2 + rng.next_below(layer_spread);
    let mut pools = 0;
    for _ in 0..n_layers {
        // Bias toward convs; cap pools so maps stay large enough.
        if pools < max_pools && rng.next_below(4) == 0 {
            ops.push(LayerKind::MaxPool { size: 2, stride: 2 });
            pools += 1;
        } else {
            let size = if rng.next_below(3) == 0 { 1 } else { 3 };
            ops.push(LayerKind::Conv {
                filters: 1 << (filter_shift_base + rng.next_below(filter_shift_spread)),
                size,
                stride: 1,
                pad: size / 2,
            });
        }
    }
    // Input extent: multiple of 8 so the pools stay even.
    let wh = 8 * (wh_base + rng.next_below(wh_spread));
    Network::from_ops("prop", wh, wh, 3, &ops)
}

fn random_network(rng: &mut SplitMix64) -> Network {
    random_network_sized(rng, 8, 3, 2, 4, 8, 9) // 64..136, filters 4..32
}

fn random_config(rng: &mut SplitMix64, net: &Network) -> MafatConfig {
    let cuts = net.candidate_cuts();
    let tiling = 1 + rng.next_below(4);
    if cuts.is_empty() || rng.next_below(3) == 0 {
        MafatConfig::no_cut(tiling)
    } else {
        let cut = cuts[rng.next_below(cuts.len())];
        MafatConfig::with_cut(tiling, cut, 1 + rng.next_below(3))
    }
}

#[test]
fn prop_network_validates() {
    cases(CASES, |rng| {
        random_network(rng).validate().unwrap();
    });
}

#[test]
fn prop_grid_partitions_exactly() {
    cases(CASES, |rng| {
        let net = random_network(rng);
        let n = 1 + rng.next_below(5);
        let bottom = net.n_layers() - 1;
        let (w, h, _) = net.out_shape(bottom);
        if n > w.min(h) {
            return;
        }
        let g = plan_group(&net, 0, bottom, n, n).unwrap();
        let total: usize = g.tasks.iter().map(|t| t.output_rect().area()).sum();
        assert_eq!(total, w * h, "tiles must partition the output map");
        // Disjoint.
        for (a, ta) in g.tasks.iter().enumerate() {
            for tb in g.tasks.iter().skip(a + 1) {
                assert_eq!(ta.output_rect().overlap_area(&tb.output_rect()), 0);
            }
        }
    });
}

#[test]
fn prop_pool_regions_window_aligned_and_shapes_consistent() {
    cases(CASES, |rng| {
        let net = random_network(rng);
        let config = random_config(rng, &net);
        let Ok(plan) = plan_config(&net, config) else { return };
        for group in &plan.groups {
            for task in &group.tasks {
                for lg in &task.layers {
                    let spec = &net.layers[lg.layer];
                    if spec.kind.is_pool() {
                        assert_eq!(lg.in_rect.x0 % 2, 0);
                        assert_eq!(lg.in_rect.w() % 2, 0);
                        assert!(!lg.pad.any());
                    }
                    let f = spec.kind.filter();
                    let s = spec.kind.stride();
                    assert_eq!(
                        down_extent(lg.in_rect.w(), lg.pad.left, lg.pad.right, f, s),
                        lg.out_rect.w()
                    );
                    assert_eq!(
                        down_extent(lg.in_rect.h(), lg.pad.top, lg.pad.bottom, f, s),
                        lg.out_rect.h()
                    );
                }
                // Layers chain.
                for w in task.layers.windows(2) {
                    assert_eq!(w[0].out_rect, w[1].in_rect);
                }
            }
        }
    });
}

#[test]
fn prop_predictor_monotone_in_tiling_when_halo_small() {
    // Monotonicity in the tiling is NOT a universal FTP property: a deep
    // fusing on a small map can make a middle tile (halo on both sides)
    // bigger than a coarser grid's corner tile. It holds whenever the
    // accumulated halo is small relative to the tile extent — the paper's
    // YOLOv2 regime. Guard accordingly.
    cases(CASES, |rng| {
        let net = random_network(rng);
        let params = PredictorParams::default();
        let (w, h, _) = net.out_shape(net.n_layers() - 1);
        let max_t = 5.min(w.min(h));
        // Accumulated one-sided halo at the top layer (upper bound).
        let halo: usize = net
            .layers
            .iter()
            .map(|l| l.kind.filter() / 2)
            .sum();
        if halo * 2 * max_t >= w.min(h) {
            return; // deep-halo regime: monotonicity not claimed
        }
        let mut prev = u64::MAX;
        for t in 1..=max_t {
            let p = predict_mem(&net, MafatConfig::no_cut(t), &params).unwrap();
            assert!(
                p.total_bytes <= prev,
                "tiling {t} increased prediction on {}x{} (halo {halo})",
                net.in_w,
                net.in_h
            );
            prev = p.total_bytes;
        }
    });
}

#[test]
fn prop_search_result_fits_or_is_fallback() {
    cases(CASES, |rng| {
        let net = random_network(rng);
        let limit = (16 + rng.next_below(300) as u64) * MIB;
        let params = PredictorParams::default();
        let r = get_config(&net, limit, &params).unwrap();
        if !r.is_fallback {
            assert!(r.predicted_bytes < limit);
        }
        // The returned config must be plannable whenever its cut exists in
        // this network (the fallback hard-codes cut 8, which a short prefix
        // may not have — the paper's algorithm is YOLOv2-specific there).
        if let Some(cut) = r.config.cut {
            if cut < net.n_layers() {
                plan_config(&net, r.config).unwrap();
            }
        } else {
            plan_config(&net, r.config).unwrap();
        }
    });
}

#[test]
fn prop_balance_spans_monotone_cover_and_bounded_effective_extent() {
    // Variable-tiling boundaries must (1) be strictly monotone, (2) cover
    // exactly [0, extent], and (3) never produce an *effective* extent
    // (tile width + halo per interior side) larger than the even grid's
    // worst tile — the balanced grid can only shrink the footprint driver.
    let effective_max = |bounds: &[usize], halo: usize| -> usize {
        let n = bounds.len() - 1;
        (0..n)
            .map(|i| {
                let w = bounds[i + 1] - bounds[i];
                let interior_sides = usize::from(i > 0) + usize::from(i + 1 < n);
                w + halo * interior_sides
            })
            .max()
            .unwrap()
    };
    cases(300, |rng| {
        let extent = 4 + rng.next_below(400);
        let n = 1 + rng.next_below(10.min(extent));
        let halo = rng.next_below(9);
        let bounds = balance_spans(extent, n, halo);
        assert_eq!(bounds.len(), n + 1, "extent {extent} n {n} halo {halo}");
        assert_eq!(bounds[0], 0);
        assert_eq!(*bounds.last().unwrap(), extent, "must cover the extent");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "boundaries must be strictly monotone: {bounds:?}"
        );
        let even: Vec<usize> = (0..=n).map(|k| k * extent / n).collect();
        assert!(
            effective_max(&bounds, halo) <= effective_max(&even, halo),
            "extent {extent} n {n} halo {halo}: balanced {bounds:?} vs even {even:?}"
        );
    });
}

/// Random strictly increasing boundary vector `0 = b0 < ... < bn = extent`
/// with up to `max_parts` spans.
fn random_bounds(rng: &mut SplitMix64, extent: usize, max_parts: usize) -> Vec<usize> {
    let n = 1 + rng.next_below(max_parts.min(extent));
    let mut interior = std::collections::BTreeSet::new();
    while interior.len() < n - 1 {
        interior.insert(1 + rng.next_below(extent - 1));
    }
    let mut b = vec![0];
    b.extend(interior);
    b.push(extent);
    b
}

#[test]
fn prop_gather_scatter_round_trip_over_arbitrary_partitions() {
    // FeatureMap::gather/scatter must be exact inverses over any rect
    // partition of a map: gathering every rect of a random boundary grid
    // and scattering the tiles into a fresh map reconstructs the original
    // map bit for bit (the engine's "merge and re-tile" correctness core).
    cases(120, |rng| {
        let h = 2 + rng.next_below(24);
        let w = 2 + rng.next_below(24);
        let c = 1 + rng.next_below(5);
        let mut map = FeatureMap::zeros(h, w, c);
        for (i, v) in map.data.iter_mut().enumerate() {
            *v = i as f32 + 0.5;
        }
        let xs = random_bounds(rng, w, 5);
        let ys = random_bounds(rng, h, 5);
        let mut rebuilt = FeatureMap::zeros(h, w, c);
        for j in 0..ys.len() - 1 {
            for i in 0..xs.len() - 1 {
                let rect = Rect::new(xs[i], ys[j], xs[i + 1], ys[j + 1]);
                let tile = map.gather(&rect);
                assert_eq!(tile.len(), rect.area() * c);
                rebuilt.scatter(&rect, &tile);
                // Per-rect inverse: gathering right back returns the tile.
                assert_eq!(rebuilt.gather(&rect), tile);
            }
        }
        assert_eq!(rebuilt.data, map.data, "partition must reconstruct the map");
    });
}

#[test]
fn prop_tiling_rects_cover_map_disjointly() {
    // Any full tiling built from explicit boundaries — the form manifests
    // serialize for variable configs — partitions the bottom output map:
    // output rects are pairwise disjoint and their areas sum to the map.
    cases(60, |rng| {
        let net = random_network(rng);
        let bottom = net.n_layers() - 1;
        let (w, h, _) = net.out_shape(bottom);
        let xs = random_bounds(rng, w, 4);
        let ys = random_bounds(rng, h, 4);
        let g = plan_group_from_bounds(&net, 0, bottom, &xs, &ys).unwrap();
        assert_eq!(g.n_tasks(), (xs.len() - 1) * (ys.len() - 1));
        let total: usize = g.tasks.iter().map(|t| t.output_rect().area()).sum();
        assert_eq!(total, w * h, "rects must cover the map");
        for (a, ta) in g.tasks.iter().enumerate() {
            for tb in g.tasks.iter().skip(a + 1) {
                assert_eq!(
                    ta.output_rect().overlap_area(&tb.output_rect()),
                    0,
                    "rects must be disjoint"
                );
            }
        }
        // Boundaries recovered from the plan are the ones we asked for.
        assert_eq!(g.bounds(), (xs, ys));
    });
}

/// A small random conv/pool net that keeps *executing* property tests fast
/// in debug builds (the geometry props above never run convs; the batched
/// execution prop below does).
fn random_small_network(rng: &mut SplitMix64) -> Network {
    random_network_sized(rng, 4, 2, 1, 3, 1, 3) // 8..24, filters 2..8
}

#[test]
fn prop_class_batched_blocked_execution_matches_scalar_sequential() {
    // The tentpole equivalence: grouping tiles by shape class — across an
    // arbitrary rect partition AND an arbitrary image batch — gathering
    // each class into one contiguous buffer, and executing it with a
    // single blocked-executor call per class must reproduce the scalar
    // per-tile sequential path byte for byte. Covers batch = 1 and uneven
    // (variable-style) boundary grids; pools included.
    cases(25, |rng| {
        let net = random_small_network(rng);
        let bottom = net.n_layers() - 1;
        let (w, h, _) = net.out_shape(bottom);
        let xs = random_bounds(rng, w, 4);
        let ys = random_bounds(rng, h, 4);
        let g = plan_group_from_bounds(&net, 0, bottom, &xs, &ys).unwrap();
        let weights = gen_network_weights(&net, WEIGHT_SEED);
        let packed = reference::pack_weights(&net, &weights);
        let n_images = 1 + rng.next_below(3);
        let images: Vec<Vec<f32>> = (0..n_images)
            .map(|i| mafat::data::gen_image(9000 + i as u64, net.in_w, net.in_h, net.in_c))
            .collect();
        let (ow, oh, oc) = net.out_shape(bottom);

        // Scalar sequential reference: per image, per task.
        let mut expected: Vec<FeatureMap> = Vec::new();
        for image in &images {
            let input = FeatureMap {
                h: net.in_h,
                w: net.in_w,
                c: net.in_c,
                data: image.clone(),
            };
            let mut out_map = FeatureMap::zeros(oh, ow, oc);
            for task in &g.tasks {
                let tile = input.gather(&task.input_rect());
                let out = reference::run_task(&net, &weights, task, &tile).unwrap();
                out_map.scatter(&task.output_rect(), &out);
            }
            expected.push(out_map);
        }

        // Class-batched blocked path: one executor call per class over
        // the (image x task) tiles of that class.
        let inputs: Vec<FeatureMap> = images
            .iter()
            .map(|image| FeatureMap {
                h: net.in_h,
                w: net.in_w,
                c: net.in_c,
                data: image.clone(),
            })
            .collect();
        let mut got: Vec<FeatureMap> =
            (0..n_images).map(|_| FeatureMap::zeros(oh, ow, oc)).collect();
        let mut class_order = Vec::new();
        let mut by_class: std::collections::HashMap<String, Vec<usize>> =
            std::collections::HashMap::new();
        for (ix, task) in g.tasks.iter().enumerate() {
            let key = task.class_key().short_name();
            by_class
                .entry(key.clone())
                .or_insert_with(|| {
                    class_order.push(key);
                    Vec::new()
                })
                .push(ix);
        }
        for key in &class_order {
            let ixs = &by_class[key];
            let mut batch = Vec::new();
            let mut pairs = Vec::new();
            for (img_i, input) in inputs.iter().enumerate() {
                for &ix in ixs {
                    batch.extend_from_slice(&input.gather(&g.tasks[ix].input_rect()));
                    pairs.push((img_i, ix));
                }
            }
            let out = reference::run_task_batch_blocked(
                &net,
                &packed,
                &g.tasks[ixs[0]],
                &batch,
                pairs.len(),
            )
            .unwrap();
            let stride = out.len() / pairs.len();
            for (slot, &(img_i, ix)) in pairs.iter().enumerate() {
                let rect = g.tasks[ix].output_rect();
                got[img_i].scatter(&rect, &out[slot * stride..][..stride]);
            }
        }

        for (e, g2) in expected.iter().zip(&got) {
            assert_eq!(e.data, g2.data, "batched blocked != scalar sequential");
        }
    });
}

/// A small random depthwise/pointwise stack (MobileNet-shaped): a full-conv
/// stem, then alternating depthwise 3x3 / pointwise 1x1 pairs with
/// occasional pools — every net is guaranteed at least one depthwise layer.
fn random_dw_pw_network(rng: &mut SplitMix64) -> Network {
    let mut ops = vec![LayerKind::Conv {
        filters: 1 << (1 + rng.next_below(3)),
        size: 3,
        stride: 1,
        pad: 1,
    }];
    let n_pairs = 1 + rng.next_below(3);
    let mut pools = 0;
    for _ in 0..n_pairs {
        ops.push(LayerKind::DepthwiseConv {
            size: 3,
            stride: 1,
            pad: 1,
        });
        ops.push(LayerKind::Conv {
            filters: 1 << (1 + rng.next_below(3)),
            size: 1,
            stride: 1,
            pad: 0,
        });
        if pools < 1 && rng.next_below(3) == 0 {
            ops.push(LayerKind::MaxPool { size: 2, stride: 2 });
            pools += 1;
        }
    }
    let wh = 8 * (1 + rng.next_below(3)); // 8..24
    Network::from_ops("prop-dw", wh, wh, 3, &ops)
}

#[test]
fn prop_depthwise_class_batched_blocked_matches_scalar_sequential() {
    // The depthwise tentpole equivalence: over arbitrary small
    // depthwise/pointwise stacks and arbitrary rect partitions, executing
    // each shape class with one blocked batched call must reproduce the
    // scalar per-tile sequential path byte for byte — and the plan's
    // boundaries must round-trip through `GroupPlan::bounds()`.
    cases(25, |rng| {
        let net = random_dw_pw_network(rng);
        let bottom = net.n_layers() - 1;
        let (w, h, _) = net.out_shape(bottom);
        let xs = random_bounds(rng, w, 4);
        let ys = random_bounds(rng, h, 4);
        let g = plan_group_from_bounds(&net, 0, bottom, &xs, &ys).unwrap();
        assert_eq!(g.bounds(), (xs, ys), "bounds must round-trip");
        let weights = gen_network_weights(&net, WEIGHT_SEED);
        let packed = reference::pack_weights(&net, &weights);
        let image = mafat::data::gen_image(7100, net.in_w, net.in_h, net.in_c);
        let input = FeatureMap {
            h: net.in_h,
            w: net.in_w,
            c: net.in_c,
            data: image,
        };
        let (ow, oh, oc) = net.out_shape(bottom);

        // Scalar sequential reference.
        let mut expected = FeatureMap::zeros(oh, ow, oc);
        for task in &g.tasks {
            let tile = input.gather(&task.input_rect());
            let out = reference::run_task(&net, &weights, task, &tile).unwrap();
            expected.scatter(&task.output_rect(), &out);
        }

        // Class-batched blocked path: one executor call per class.
        let mut got = FeatureMap::zeros(oh, ow, oc);
        let mut by_class: std::collections::HashMap<String, Vec<usize>> =
            std::collections::HashMap::new();
        for (ix, task) in g.tasks.iter().enumerate() {
            by_class
                .entry(task.class_key().short_name())
                .or_default()
                .push(ix);
        }
        for ixs in by_class.values() {
            let mut batch = Vec::new();
            for &ix in ixs {
                batch.extend_from_slice(&input.gather(&g.tasks[ix].input_rect()));
            }
            let out = reference::run_task_batch_blocked(
                &net,
                &packed,
                &g.tasks[ixs[0]],
                &batch,
                ixs.len(),
            )
            .unwrap();
            let stride = out.len() / ixs.len();
            for (slot, &ix) in ixs.iter().enumerate() {
                got.scatter(&g.tasks[ix].output_rect(), &out[slot * stride..][..stride]);
            }
        }
        assert_eq!(expected.data, got.data, "batched blocked != scalar sequential");
    });
}

#[test]
fn prop_threaded_batch_matches_sequential_for_arbitrary_partitions() {
    // The intra-worker parallelism equivalence: for arbitrary rect
    // partitions, image batches, and team sizes — including teams larger
    // than the tile count — the threaded executor must reproduce the
    // sequential blocked path byte for byte. Threads only split the
    // (image x tile) pairs into contiguous chunks written to disjoint
    // output regions, so equality is exact, not approximate.
    cases(15, |rng| {
        let net = random_small_network(rng);
        let bottom = net.n_layers() - 1;
        let (w, h, _) = net.out_shape(bottom);
        let xs = random_bounds(rng, w, 4);
        let ys = random_bounds(rng, h, 4);
        let g = plan_group_from_bounds(&net, 0, bottom, &xs, &ys).unwrap();
        let weights = gen_network_weights(&net, WEIGHT_SEED);
        let packed = reference::pack_weights(&net, &weights);
        let n_images = 1 + rng.next_below(3);
        let inputs: Vec<FeatureMap> = (0..n_images)
            .map(|i| FeatureMap {
                h: net.in_h,
                w: net.in_w,
                c: net.in_c,
                data: mafat::data::gen_image(4400 + i as u64, net.in_w, net.in_h, net.in_c),
            })
            .collect();

        // One shape class at a time, exactly as the engine batches them.
        let mut by_class: std::collections::HashMap<String, Vec<usize>> =
            std::collections::HashMap::new();
        for (ix, task) in g.tasks.iter().enumerate() {
            by_class
                .entry(task.class_key().short_name())
                .or_default()
                .push(ix);
        }
        for ixs in by_class.values() {
            let mut batch = Vec::new();
            for input in &inputs {
                for &ix in ixs {
                    batch.extend_from_slice(&input.gather(&g.tasks[ix].input_rect()));
                }
            }
            let n_tiles = ixs.len() * n_images;
            let sequential =
                reference::run_task_batch_blocked(&net, &packed, &g.tasks[ixs[0]], &batch, n_tiles)
                    .unwrap();
            let team = 1 + rng.next_below(n_tiles + 2); // includes threads > tiles
            let threaded = parallel::run_task_batch_blocked_threaded(
                &net,
                &packed,
                &g.tasks[ixs[0]],
                &batch,
                n_tiles,
                team,
            )
            .unwrap();
            assert_eq!(
                sequential.len(),
                threaded.len(),
                "threaded output length diverged at team {team}"
            );
            assert_eq!(
                sequential, threaded,
                "threaded != sequential for {n_tiles} tiles on a team of {team}"
            );
        }
    });
}

#[test]
fn prop_reuse_schedule_is_permutation_and_even_first() {
    cases(CASES, |rng| {
        let net = random_network(rng);
        let n = 1 + rng.next_below(4);
        let bottom = net.n_layers() - 1;
        let (w, h, _) = net.out_shape(bottom);
        if n > w.min(h) {
            return;
        }
        let g = plan_group(&net, 0, bottom, n, n).unwrap();
        let order = schedule_order(&g);
        let mut seen = vec![false; g.tasks.len()];
        let mut parity_flip = 0;
        let mut last_parity = 0;
        for &ix in &order {
            assert!(!seen[ix], "duplicate task in schedule");
            seen[ix] = true;
            let t = &g.tasks[ix];
            let p = (t.grid_i + t.grid_j) % 2;
            if p != last_parity {
                parity_flip += 1;
                last_parity = p;
            }
        }
        assert!(seen.iter().all(|&s| s), "schedule misses tasks");
        assert!(parity_flip <= 1, "parity interleaved: schedule not even-first");
    });
}

#[test]
fn prop_reuse_never_increases_macs() {
    cases(30, |rng| {
        let net = random_network(rng);
        let n = 1 + rng.next_below(4);
        let bottom = net.n_layers() - 1;
        let (w, h, _) = net.out_shape(bottom);
        if n > w.min(h) {
            return;
        }
        let g = plan_group(&net, 0, bottom, n, n).unwrap();
        let r = reuse_analysis(&net, &g);
        assert!(r.total_macs <= r.naive_macs);
        // And never below the untiled ideal.
        let untiled: u64 = plan_group(&net, 0, bottom, 1, 1).unwrap().tasks[0].macs(&net);
        assert!(
            r.total_macs >= untiled,
            "reuse 'saved' more work than exists: {} < {untiled}",
            r.total_macs
        );
    });
}

#[test]
fn prop_config_display_parse_round_trip() {
    cases(200, |rng| {
        let config = MafatConfig {
            top_tiling: 1 + rng.next_below(9),
            cut: if rng.next_below(2) == 0 {
                None
            } else {
                Some(1 + rng.next_below(20))
            },
            bottom_tiling: 1 + rng.next_below(9),
        };
        let text = config.to_string();
        let back: MafatConfig = text.parse().unwrap();
        // NoCut normalizes bottom_tiling to 1.
        if config.cut.is_some() {
            assert_eq!(back, config);
        } else {
            assert_eq!(back.top_tiling, config.top_tiling);
            assert_eq!(back.cut, None);
        }
    });
}

#[test]
fn prop_governor_drain_bounded_and_monotone_in_budget() {
    // The governor's drain derivation (ISSUE 5 satellite): for arbitrary
    // per-image predictions, batch caps, and worker counts, the derived
    // drain is >= 1, never exceeds max(1, max_batch / workers), and is
    // monotone non-decreasing as the budget headroom grows.
    cases(CASES, |rng| {
        let predicted = 1 + rng.next_below(1 << 24) as u64;
        let max_batch = rng.next_below(64);
        let workers = rng.next_below(8);
        let cap = (max_batch / workers.max(1)).max(1);
        let mut budget = 0u64;
        let mut prev = 0usize;
        for step in 0..24 {
            budget += rng.next_below(1 << 26) as u64;
            let drain = derive_drain(budget, predicted, max_batch, workers);
            assert!(drain >= 1, "drain {drain} at budget {budget}");
            assert!(
                drain <= cap,
                "drain {drain} > cap {cap} (max_batch {max_batch}, workers {workers})"
            );
            assert!(
                drain >= prev,
                "step {step}: drain {drain} < {prev} though the budget only grew"
            );
            prev = drain;
        }
        // Degenerate prediction (0 bytes/image) falls back to the cap.
        assert_eq!(derive_drain(budget, 0, max_batch, workers), cap);
    });
}

/// A random bucket: rate in [0, ~8)/s (quarters, so zero-rate shows up),
/// burst in [1, 17) (halves).
fn random_bucket(rng: &mut SplitMix64) -> TokenBucket {
    let rate = rng.next_below(32) as f64 / 4.0;
    let burst = 1.0 + rng.next_below(32) as f64 / 2.0;
    TokenBucket::new(rate, burst).unwrap()
}

#[test]
fn prop_token_bucket_never_exceeds_burst_and_rejects_at_zero_rate() {
    // Admission invariants (ISSUE 9 satellite): however the clock moves —
    // forward, stalled, or backwards — the token count stays within
    // [0, burst], and a zero-rate bucket admits nothing, ever.
    cases(CASES, |rng| {
        let mut b = random_bucket(rng);
        let zero_rate = b.rate() == 0.0;
        let mut now = 0.0f64;
        for _ in 0..40 {
            // Mostly forward steps, occasionally a stall or a skew jump back.
            now += rng.next_below(9) as f64 / 2.0 - 0.5;
            let preview = b.tokens_at(now);
            assert!((0.0..=b.burst()).contains(&preview), "preview {preview}");
            let admitted = b.admit_at(now);
            assert!((0.0..=b.burst()).contains(&b.tokens_at(now)));
            if zero_rate {
                assert!(!admitted, "zero-rate bucket admitted at t={now}");
            }
        }
    });
}

#[test]
fn prop_token_bucket_long_run_admissions_bounded_by_rate() {
    // Over any forward-moving schedule, total admissions can never exceed
    // the initial burst plus what the rate refilled: burst + rate*elapsed.
    cases(CASES, |rng| {
        let mut b = random_bucket(rng);
        let mut now = 0.0f64;
        let mut admitted = 0u32;
        for _ in 0..200 {
            now += rng.next_below(8) as f64 / 8.0;
            if b.admit_at(now) {
                admitted += 1;
            }
        }
        let bound = b.burst() + b.rate() * now;
        assert!(
            (admitted as f64) <= bound + 1e-9,
            "admitted {admitted} > burst {} + rate {} * {now}",
            b.burst(),
            b.rate()
        );
    });
}

#[test]
fn prop_token_bucket_refill_preview_monotone_in_time() {
    // tokens_at is a pure preview: for t1 <= t2 it never shrinks, and it
    // never mutates the bucket (repeated previews agree).
    cases(CASES, |rng| {
        let mut b = random_bucket(rng);
        // Age the bucket through a few random consuming calls first.
        let mut now = 0.0f64;
        for _ in 0..rng.next_below(6) {
            now += rng.next_below(4) as f64;
            b.admit_at(now);
        }
        let mut t = now - 2.0;
        let mut prev = b.tokens_at(t);
        for _ in 0..30 {
            t += rng.next_below(8) as f64 / 4.0;
            let tokens = b.tokens_at(t);
            assert_eq!(tokens, b.tokens_at(t), "preview must not mutate");
            assert!(
                tokens >= prev,
                "preview shrank from {prev} to {tokens} as time advanced to {t}"
            );
            prev = tokens;
        }
    });
}
