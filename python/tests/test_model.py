"""L2 model correctness: fused group forward vs layer-by-layer reference,
and pure-JAX tiled-vs-untiled equivalence on hand-built geometry."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.model import LayerCfg, LayerGeom, fused_task_forward, full_forward, init_params

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")

# A miniature YOLOv2-style prefix: conv3, pool, conv3, conv1.
MINI = [
    LayerCfg("conv", 3, 8, 3, 1),
    LayerCfg("max", 8, 8, 2, 2),
    LayerCfg("conv", 8, 16, 3, 1),
    LayerCfg("conv", 16, 8, 1, 1),
]


def mini_weights(seed=0):
    return [p for p in init_params(MINI, seed) if p is not None]


def test_full_forward_pallas_vs_ref():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 16, 3)), jnp.float32)
    w = mini_weights()
    got = np.asarray(full_forward(x, w, MINI, use_pallas=True))
    want = np.asarray(full_forward(x, w, MINI, use_pallas=False))
    assert got.shape == (8, 8, 8)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def up_span(o0, o1, f, s, p, extent):
    """Mirror of rust ftp::traversal::up_span (kept in sync by the
    cross-language geometry tests in rust/tests/)."""
    lo = o0 * s - p
    hi = (o1 - 1) * s - p + f
    clo, chi = max(lo, 0), min(hi, extent)
    return clo, chi, clo - lo, hi - chi


def build_task_geometry(layers, out_rect, extents):
    """Walk a tile up through `layers` (bottom->top), producing LayerGeoms
    and the task input rect. extents[l] = (in_w, in_h) of layer l."""
    geoms = []
    rect = out_rect  # (x0, y0, x1, y1) on the bottom layer's output
    for li in reversed(range(len(layers))):
        cfg = layers[li]
        in_w, in_h = extents[li]
        f = cfg.size
        s = cfg.stride
        p = cfg.size // 2 if (cfg.is_conv and cfg.size > 1) else 0
        x0, x1, pl, pr = up_span(rect[0], rect[2], f, s, p, in_w)
        y0, y1, pt, pb = up_span(rect[1], rect[3], f, s, p, in_h)
        geoms.append(
            LayerGeom(
                in_w=x1 - x0,
                in_h=y1 - y0,
                out_w=rect[2] - rect[0],
                out_h=rect[3] - rect[1],
                pads=(pt, pb, pl, pr),
            )
        )
        rect = (x0, y0, x1, y1)
    geoms.reverse()
    return geoms, rect


@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([2, 4]))
def test_tiled_equals_untiled(seed, n):
    """The FTP invariant in pure JAX: fusing+tiling reproduces the untiled
    output exactly (paper §2.1.1 'mathematically equivalent')."""
    rng = np.random.default_rng(seed)
    H = W = 16
    x = jnp.asarray(rng.normal(size=(H, W, 3)), jnp.float32)
    w = mini_weights(seed % 7)
    want = np.asarray(full_forward(x, w, MINI, use_pallas=False))

    extents = [(16, 16), (16, 16), (8, 8), (8, 8)]  # input extent per layer
    OH = OW = 8
    got = np.zeros_like(want)
    step = OH // n
    for j in range(n):
        for i in range(n):
            out_rect = (i * step, j * step, (i + 1) * step, (j + 1) * step)
            geoms, in_rect = build_task_geometry(MINI, out_rect, extents)
            tile = x[in_rect[1]:in_rect[3], in_rect[0]:in_rect[2], :]
            out = fused_task_forward(tile, w, MINI, geoms, use_pallas=False)
            got[out_rect[1]:out_rect[3], out_rect[0]:out_rect[2], :] = np.asarray(out)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_geometry_shape_assertion_fires():
    """A wrong geometry must be caught by the shape assertion, not produce
    silently wrong output."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 16, 3)), jnp.float32)
    w = mini_weights()
    bad = [
        LayerGeom(16, 16, 16, 16, (1, 1, 1, 1)),
        LayerGeom(16, 16, 9, 8, (0, 0, 0, 0)),  # wrong out_w
        LayerGeom(8, 8, 8, 8, (1, 1, 1, 1)),
        LayerGeom(8, 8, 8, 8, (0, 0, 0, 0)),
    ]
    try:
        fused_task_forward(x, w, MINI, bad, use_pallas=False)
    except AssertionError:
        return
    raise AssertionError("bad geometry was not caught")
