"""L1 kernel correctness: Pallas conv/maxpool vs the pure-jnp oracle.

Hypothesis sweeps shapes, channel counts, filter sizes, and per-side
paddings — the exact degrees of freedom the fused-tile geometry exercises.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, conv2d_ref, maxpool2d, maxpool2d_ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@given(
    h=st.integers(3, 14),
    w=st.integers(3, 14),
    cin=st.integers(1, 9),
    cout=st.integers(1, 9),
    f=st.sampled_from([1, 3]),
    pt=st.integers(0, 1),
    pb=st.integers(0, 1),
    pl=st.integers(0, 1),
    pr=st.integers(0, 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref(h, w, cin, cout, f, pt, pb, pl, pr, seed):
    if f == 1:
        pt = pb = pl = pr = 0
    # The padded input must be at least as large as the filter.
    if h + pt + pb < f or w + pl + pr < f:
        return
    rng = np.random.default_rng(seed)
    x = rand(rng, h, w, cin)
    wts = rand(rng, f, f, cin, cout)
    b = rand(rng, cout)
    pads = (pt, pb, pl, pr)
    got = np.asarray(conv2d(x, wts, b, pads=pads))
    want = np.asarray(conv2d_ref(x, wts, b, pads=pads))
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    h=st.integers(1, 10),
    w=st.integers(1, 10),
    c=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(h, w, c, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, 2 * h, 2 * w, c)
    got = np.asarray(maxpool2d(x))
    want = np.asarray(maxpool2d_ref(x))
    np.testing.assert_allclose(got, want)


def test_maxpool_rejects_unaligned():
    x = jnp.zeros((5, 6, 2), jnp.float32)
    with pytest.raises(AssertionError):
        maxpool2d(x)


def test_conv_no_activation():
    rng = np.random.default_rng(0)
    x = rand(rng, 6, 6, 3)
    w = rand(rng, 3, 3, 3, 4)
    b = rand(rng, 4)
    got = np.asarray(conv2d(x, w, b, pads=(1, 1, 1, 1), apply_act=False))
    want = np.asarray(conv2d_ref(x, w, b, pads=(1, 1, 1, 1), apply_act=False))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # Negative values survive without the leaky slope.
    assert (got < 0).any()


def test_leaky_relu_applied():
    # With a large negative bias every output is negative; leaky scales by 0.1.
    rng = np.random.default_rng(1)
    x = jnp.asarray(np.abs(rng.normal(size=(4, 4, 2))), jnp.float32)
    w = jnp.asarray(np.zeros((1, 1, 2, 3)), jnp.float32)
    b = jnp.asarray([-10.0, -20.0, -30.0], jnp.float32)
    out = np.asarray(conv2d(x, w, b, pads=(0, 0, 0, 0)))
    np.testing.assert_allclose(out[..., 0], -1.0, rtol=1e-5)
    np.testing.assert_allclose(out[..., 2], -3.0, rtol=1e-5)


def test_wide_channel_blocks():
    # Cout > OC block forces a multi-step grid.
    rng = np.random.default_rng(2)
    x = rand(rng, 5, 5, 8)
    w = rand(rng, 3, 3, 8, 300)
    b = rand(rng, 300)
    got = np.asarray(conv2d(x, w, b, pads=(1, 1, 1, 1), oc_block=128))
    want = np.asarray(conv2d_ref(x, w, b, pads=(1, 1, 1, 1)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
