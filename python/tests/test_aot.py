"""AOT pipeline tests: geometry JSON -> HLO text -> manifest, checked
against golden shapes (a miniature network, so the test runs in seconds)."""

import json
import os
import tempfile

from compile import aot
from compile.model import layers_from_json


def mini_geometry():
    """A hand-written geometry request in the exact schema
    `mafat export-geometry` emits: an 8x8x3 conv3+pool network, 2x2 tiled."""
    return {
        "version": 1,
        "networks": [
            {
                "name": "tiny",
                "in_w": 8,
                "in_h": 8,
                "in_c": 3,
                "layers": [
                    {"kind": "conv", "filters": 4, "size": 3, "stride": 1, "pad": 1},
                    {"kind": "max", "size": 2, "stride": 2},
                ],
                "emit_full": True,
                "configs": [
                    {
                        "config": "2x2/NoCut",
                        "groups": [
                            {
                                "gi": 0,
                                "top": 0,
                                "bottom": 1,
                                "n": 2,
                                "m": 2,
                                "classes": [
                                    {
                                        "key": "corner",
                                        "layers": [
                                            # conv: out 4x4 region + halo ->
                                            # in 5x5, one padded corner
                                            {"layer": 0, "in_w": 5, "in_h": 5,
                                             "out_w": 4, "out_h": 4,
                                             "pt": 1, "pb": 0, "pl": 1, "pr": 0},
                                            {"layer": 1, "in_w": 4, "in_h": 4,
                                             "out_w": 2, "out_h": 2,
                                             "pt": 0, "pb": 0, "pl": 0, "pr": 0},
                                        ],
                                    }
                                ],
                                "tasks": [
                                    {"i": 0, "j": 0, "class": "corner",
                                     "in_rect": [0, 0, 5, 5],
                                     "out_rect": [0, 0, 2, 2]}
                                ],
                            }
                        ],
                    }
                ],
            }
        ],
    }


def test_build_emits_hlo_and_manifest():
    geo = mini_geometry()
    with tempfile.TemporaryDirectory() as out:
        manifest = aot.build(geo, out, verbose=False)
        net = manifest["networks"][0]
        # Full oracle present with the right shapes.
        assert net["full"]["in"] == [8, 8, 3]
        assert net["full"]["out"] == [4, 4, 4]
        assert os.path.exists(os.path.join(out, net["full"]["path"]))
        # One class module with echoed geometry.
        klass = net["configs"][0]["groups"][0]["classes"][0]
        assert klass["in"] == [5, 5, 3]
        assert klass["out"] == [2, 2, 4]
        hlo_path = os.path.join(out, klass["path"])
        assert os.path.exists(hlo_path)
        text = open(hlo_path).read()
        # HLO text sanity: an entry computation over f32 with the right
        # parameter shapes (input tile + conv weights + bias).
        assert "ENTRY" in text
        assert "f32[5,5,3]" in text
        assert "f32[3,3,3,4]" in text
        assert "f32[4]" in text
        # Manifest is valid JSON and round-trips.
        s = json.dumps(manifest)
        assert json.loads(s) == manifest


def test_layers_from_json_chains_channels():
    net = mini_geometry()["networks"][0]
    layers = layers_from_json(net)
    assert layers[0].in_c == 3 and layers[0].out_c == 4
    assert layers[1].in_c == 4 and layers[1].out_c == 4


def test_sanitize_names():
    assert aot.sanitize("5x5/8/2x2") == "55_8_22"
    assert aot.sanitize("1x1/NoCut") == "11_NoCut"
