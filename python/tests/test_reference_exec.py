"""Cross-language verification of the Rust reference executor + tiler.

`_reference_port.py` is a line-by-line numpy/float32 port of
`rust/src/runtime/reference.rs` (conv + bias + leaky ReLU, VALID maxpool),
the tiler geometry (`ftp::traversal`/`grid`/`variable`), the engine's
gather/scatter group loop, and the deterministic weight/image generators
(`data::SplitMix64`). These tests pin the PR's numerical claims in an
environment with no Rust toolchain:

* tiled execution is **bit-identical** to the untiled oracle — for even
  grids, k-group cuts, and genuinely uneven balanced boundaries (the
  paper's §2.1.1 equivalence, checked in f32 with the executor's exact
  accumulation order);
* the **blocked** fast path (packed OC_LANES-padded weights, BLOCK_W-pixel
  microkernel) and the **class-batched** engine loop are bit-identical to
  the scalar sequential path — the PR 4 layout/batching change never
  touches any output element's f32 op order;
* the balanced-boundary search moves boundaries where the halo allows it;
* the tiny-serve prediction ordering assumed by
  `rust/tests/integration_serve.rs::auto_pick_serves_variable_config_when_it_wins`
  holds (the `4v4/2/4x4` entry is the unique predicted floor);
* **depthwise convs** (the PR 6 `LayerKind::DepthwiseConv` kind) thread
  through the same claims: scalar == blocked bit-exact on every padding
  combination, fused+tiled == untiled on a MobileNet-style stack (scalar
  and class-batched blocked), per-channel weight/scratch accounting
  matches hand-computed bytes, packed lanes pad without perturbing values,
  and no output channel ever reads another channel's input.

Pure numpy — no jax required. Run: pytest python/tests/test_reference_exec.py
"""

import numpy as np

import _reference_port as port
from _reference_port import (
    MIB,
    balance_spans,
    class_key,
    conv,
    dw,
    engine_infer_batched,
    engine_load,
    engine_reconfigure,
    engine_with_shared,
    gather,
    gen_image,
    gen_network_weights,
    grid_bounds,
    group_weight_bytes,
    infer,
    infer_batched,
    maxpool,
    mobilenet_tiny_ops,
    pack_weights,
    peak_tile_bytes,
    plan_from_bounds,
    plan_group,
    plan_group_balanced_searched,
    plan_multi,
    predict_multi_bytes,
    resolve,
    run_full,
    run_task,
    run_task_batch_blocked,
    run_task_blocked,
    yolov2_16_ops,
)


def tiny_layers():
    return resolve([conv(4, 3), maxpool(), conv(8, 3)], 16, 16, 3)


def oracle_for(layers, seed=11):
    weights = gen_network_weights(layers)
    w, h, c = layers[0].in_w, layers[0].in_h, layers[0].in_c
    img = gen_image(seed, w, h, c).reshape(h, w, c)
    return weights, img, run_full(layers, weights, img)


def test_even_tiling_bit_identical_to_oracle():
    layers = tiny_layers()
    weights, img, oracle = oracle_for(layers)
    tiled = infer(layers, weights, plan_multi(layers, "2x2/NoCut"), img)
    assert np.array_equal(tiled, oracle)


def test_k_group_cut_bit_identical_to_oracle():
    layers = tiny_layers()
    weights, img, oracle = oracle_for(layers)
    tiled = infer(layers, weights, plan_multi(layers, "2x2/1/2x2"), img)
    assert np.array_equal(tiled, oracle)


def test_uneven_balanced_boundaries_bit_identical_to_oracle():
    # Three SAME convs on 24x24: the halo-balanced search produces truly
    # uneven spans, and execution from those boundaries still matches the
    # oracle bit for bit.
    layers = resolve([conv(8, 3), conv(8, 3), conv(8, 3)], 24, 24, 3)
    tasks, xs, ys = plan_group_balanced_searched(layers, 0, 2, 3)
    assert xs != grid_bounds(3, 24), "boundaries must move"
    assert xs == [0, 8, 15, 24]  # pinned: deterministic search result
    weights, img, oracle = oracle_for(layers, seed=5)
    tiled = infer(layers, weights, [tasks], img)
    assert np.array_equal(tiled, oracle)


def test_balance_spans_partitions():
    for extent, n, halo in [(24, 3, 2), (20, 3, 2), (38, 5, 2), (6, 5, 2)]:
        b = balance_spans(extent, n, halo)
        assert b[0] == 0 and b[-1] == extent and len(b) == n + 1
        assert all(b[i] < b[i + 1] for i in range(n))


def test_arbitrary_bounds_partition_and_execute():
    layers = tiny_layers()
    weights, img, oracle = oracle_for(layers, seed=3)
    # A deliberately lopsided partition of the 8x8 output map.
    tasks = plan_from_bounds(layers, 0, 2, [0, 1, 8], [0, 5, 8])
    areas = sum(
        (t.output_rect()[2] - t.output_rect()[0]) * (t.output_rect()[3] - t.output_rect()[1])
        for t in tasks
    )
    assert areas == 8 * 8
    tiled = infer(layers, weights, [tasks], img)
    assert np.array_equal(tiled, oracle)


def test_yolo_structure_5v5_12_3v3_plans():
    # The variable search winner's shape on the (narrowed) YOLOv2-16
    # structure: 25 + 9 tasks, every group's rects partition its map.
    narrow = [
        conv(4, 3), maxpool(), conv(8, 3), maxpool(),
        conv(16, 3), conv(8, 1), conv(16, 3), maxpool(),
        conv(32, 3), conv(16, 1), conv(32, 3), maxpool(),
        conv(64, 3), conv(32, 1), conv(64, 3), conv(32, 1),
    ]
    layers = resolve(narrow, 80, 80, 3)
    groups = plan_multi(layers, "5v5/12/3v3")
    assert [len(g) for g in groups] == [25, 9]
    weights, img, oracle = oracle_for(layers, seed=7)
    tiled = infer(layers, weights, groups, img)
    assert np.array_equal(tiled, oracle)


def test_tiny_serve_prediction_ordering():
    # rust/tests/integration_serve.rs builds its auto-pick scenario on this
    # ordering: the balanced `4v4/2/4x4` entry is the unique predicted
    # floor of the tiny-serve bundle.
    layers = resolve(
        [conv(8, 3), maxpool(), conv(16, 3), maxpool(), conv(16, 1), conv(16, 3)],
        32, 32, 3,
    )
    preds = {
        cfg: predict_multi_bytes(layers, cfg)
        for cfg in ["1x1/NoCut", "2x2/NoCut", "2x2/2/2x2/4/1x1", "4v4/2/4x4"]
    }
    floor = min(preds, key=preds.get)
    assert floor == "4v4/2/4x4", preds
    others = min(v for k, v in preds.items() if k != floor)
    assert preds[floor] < others
    # Bias dominates but the margin is real (> 8 KB of peak difference).
    assert others - preds[floor] > 8 * 1024


def test_wrong_weight_free_layers_are_pools():
    layers = resolve(yolov2_16_ops(), 48, 48, 3)
    weights = gen_network_weights(layers)
    assert [w is None for w in weights] == [not l.is_conv for l in layers]


# ---------------------------------------------------- blocked fast path pins


def test_blocked_task_bit_identical_to_scalar_every_pad_combo():
    # All 9 tiles of a 3x3 tiling hit every corner/edge/center padding
    # combination; the blocked layout must reproduce the scalar path bit
    # for bit on each (the arithmetic-order claim the Rust fast path
    # relies on).
    layers = tiny_layers()
    weights = gen_network_weights(layers)
    packed = pack_weights(layers, weights)
    img = gen_image(13, 16, 16, 3).reshape(16, 16, 3)
    tasks = plan_group(layers, 0, 2, 3, 3)
    for t in tasks:
        tile = gather(img, t.input_rect())
        scalar = run_task(layers, weights, t, tile)
        blocked = run_task_blocked(layers, packed, t, tile)
        assert np.array_equal(scalar, blocked), (t.grid_i, t.grid_j)


def test_blocked_full_forward_bit_identical_to_scalar_oracle():
    layers = tiny_layers()
    weights, img, oracle = oracle_for(layers, seed=19)
    packed = pack_weights(layers, weights)
    tasks = plan_group(layers, 0, 2, 1, 1)
    blocked = run_task_blocked(layers, packed, tasks[0], img)
    assert np.array_equal(blocked, oracle)


def test_batched_class_call_equals_per_tile_calls():
    # One batched call over all tiles of a class == per-tile calls,
    # element for element (the engine's single-call-per-class shape).
    layers = tiny_layers()
    weights = gen_network_weights(layers)
    packed = pack_weights(layers, weights)
    img = gen_image(23, 16, 16, 3).reshape(16, 16, 3)
    tasks = plan_group(layers, 0, 2, 4, 4)
    by_class = {}
    for t in tasks:
        by_class.setdefault(class_key(t), []).append(t)
    multi = max(by_class.values(), key=len)
    assert len(multi) > 1, "want a real multi-tile class"
    tiles = [gather(img, t.input_rect()) for t in multi]
    batched = run_task_batch_blocked(layers, packed, multi[0], tiles)
    for t, tile, out in zip(multi, tiles, batched):
        single = run_task_blocked(layers, packed, t, tile)
        assert np.array_equal(out, single), (t.grid_i, t.grid_j)


def test_batched_infer_bit_identical_to_sequential_k_group_and_variable():
    # The engine-loop equivalence: class-batched batched inference over a
    # batch of images equals the per-image sequential scalar loop bitwise,
    # for a k-group cut AND a variable (balanced) config — and batch = 1.
    layers = tiny_layers()
    weights = gen_network_weights(layers)
    images = [gen_image(100 + i, 16, 16, 3).reshape(16, 16, 3) for i in range(3)]
    for cfg in ["2x2/1/2x2", "3v3/NoCut"]:
        groups = plan_multi(layers, cfg)
        expected = [infer(layers, weights, groups, img) for img in images]
        got = infer_batched(layers, weights, groups, images)
        for e, g in zip(expected, got):
            assert np.array_equal(e, g), cfg
        one = infer_batched(layers, weights, groups, images[:1])
        assert np.array_equal(one[0], expected[0]), cfg


def test_reconfigure_then_infer_matches_fresh_load_k_group_and_variable():
    # The PR 5 load/plan split: an engine hot-swapped onto another config
    # (plan stage only, shared weight stage) must produce bit-identical
    # output to a freshly loaded engine of that config — for a k-group cut
    # AND a variable (TvT) config.
    layers = tiny_layers()
    img = gen_image(31, 16, 16, 3).reshape(16, 16, 3)
    packs_before = port.PACK_WEIGHTS_CALLS
    eng = engine_load(layers, "2x2/NoCut")
    assert port.PACK_WEIGHTS_CALLS - packs_before == 1, "load packs once"
    packs_loaded = port.PACK_WEIGHTS_CALLS
    for cfg in ["2x2/1/2x2", "3v3/NoCut"]:
        engine_reconfigure(eng, cfg)
        assert eng['config'] == cfg
        got = engine_infer_batched(eng, [img])[0]
        fresh = engine_load(layers, cfg)  # its own weight stage: packs once
        want = engine_infer_batched(fresh, [img])[0]
        assert np.array_equal(got, want), cfg
    # Only the two fresh loads packed; reconfigure itself never does.
    assert port.PACK_WEIGHTS_CALLS - packs_loaded == 2


def test_shared_weight_stage_packs_once_across_engines():
    # Two engines on one shared stage (the worker-pool shape) pack once
    # total, and agree bit for bit with each other.
    layers = tiny_layers()
    img = gen_image(37, 16, 16, 3).reshape(16, 16, 3)
    packs_before = port.PACK_WEIGHTS_CALLS
    shared = port.engine_shared(layers)
    a = engine_with_shared(shared, "2x2/NoCut")
    b = engine_with_shared(shared, "2x2/1/2x2")
    assert port.PACK_WEIGHTS_CALLS - packs_before == 1
    out_a = engine_infer_batched(a, [img])[0]
    out_b = engine_infer_batched(b, [img])[0]
    assert np.array_equal(out_a, out_b)


def test_batched_infer_on_uneven_balanced_boundaries():
    # Genuinely uneven balanced spans (the [0, 8, 15, 24] pin above), run
    # through the blocked batched path: still bit-identical to the scalar
    # oracle.
    layers = resolve([conv(8, 3), conv(8, 3), conv(8, 3)], 24, 24, 3)
    tasks, xs, _ = plan_group_balanced_searched(layers, 0, 2, 3)
    assert xs == [0, 8, 15, 24]
    weights, img, oracle = oracle_for(layers, seed=5)
    got = infer_batched(layers, weights, [tasks], [img])
    assert np.array_equal(got[0], oracle)


# ------------------------------------------------------------ depthwise pins


def mobilenet_tiny_layers():
    return resolve(mobilenet_tiny_ops(), 16, 16, 3)


def test_depthwise_blocked_bit_identical_to_scalar_every_pad_combo():
    # All 9 tiles of a 3x3 tiling over the full MobileNet-tiny stack hit
    # every corner/edge/center padding combination through both depthwise
    # and pointwise layers; blocked must equal scalar bit for bit.
    layers = mobilenet_tiny_layers()
    weights = gen_network_weights(layers)
    packed = pack_weights(layers, weights)
    img = gen_image(41, 16, 16, 3).reshape(16, 16, 3)
    tasks = plan_group(layers, 0, len(layers) - 1, 3, 3)
    for t in tasks:
        tile = gather(img, t.input_rect())
        scalar = run_task(layers, weights, t, tile)
        blocked = run_task_blocked(layers, packed, t, tile)
        assert np.array_equal(scalar, blocked), (t.grid_i, t.grid_j)


def test_depthwise_fused_tiled_bit_identical_to_untiled():
    # Fused configs cutting through the depthwise-separable stack — the
    # even 2x2 cut and an uneven balanced 3v3 top group — both equal the
    # untiled scalar oracle bit for bit, scalar and class-batched blocked.
    layers = mobilenet_tiny_layers()
    weights, img, oracle = oracle_for(layers, seed=43)
    for cfg in ["2x2/4/2x2", "3v3/4/2x2"]:
        groups = plan_multi(layers, cfg)
        tiled = infer(layers, weights, groups, img)
        assert np.array_equal(tiled, oracle), cfg
        batched = infer_batched(layers, weights, groups, [img, img])
        for got in batched:
            assert np.array_equal(got, oracle), cfg


def test_depthwise_peak_and_weight_accounting_hand_computed():
    # Mirror of rust predictor::depthwise_peak_accounting_matches_hand_
    # computation: one 3x3 depthwise on 8x8x4, untiled. Scratch drops the
    # channel factor (8*8*9 floats), weights are C*k*k (4*9 floats):
    #   peak  = (576 + 256 + 2*256) * 4 = 5376 bytes
    #   weights = 4 * 9 * 4           =  144 bytes
    layers = resolve([dw(3)], 8, 8, 4)
    tasks = plan_group(layers, 0, 0, 1, 1)
    assert peak_tile_bytes(layers, tasks) == 5376
    assert group_weight_bytes(layers, 0, 0) == 144


def test_depthwise_does_not_mix_channels():
    # A center-tap identity filter on channel 0 and a doubling tap on
    # channel 1: each output channel sees only its own input channel, and
    # the leaky ReLU applies per channel (0.1 * -3.0 rounds exactly to
    # -0.3 in f32, so the comparison is exact).
    layers = resolve([dw(3)], 1, 1, 2)
    w = np.zeros((3, 3, 2), dtype=np.float32)
    w[1, 1, 0] = 1.0
    w[1, 1, 1] = 2.0
    b = np.zeros(2, dtype=np.float32)
    weights = [(w, b)]
    img = np.array([[[500.0, -1.5]]], dtype=np.float32)
    out = run_full(layers, weights, img)
    assert out.shape == (1, 1, 2)
    assert np.array_equal(out[0, 0], np.float32([500.0, -0.3]))
    blocked = run_task_blocked(
        layers, pack_weights(layers, weights), plan_group(layers, 0, 0, 1, 1)[0], img)
    assert np.array_equal(blocked, out)


def test_depthwise_packing_pads_lanes_and_preserves_values():
    # in_c = 3 is not a lane multiple: the packed depthwise layer pads the
    # channel axis to OC_LANES with zeros and copies values untouched.
    layers = resolve([dw(3)], 4, 4, 3)
    weights = gen_network_weights(layers)
    wp, bp, out_c = pack_weights(layers, weights)[0]
    w, b = weights[0]
    assert out_c == 3
    assert wp.shape == (3, 3, port.OC_LANES)
    assert np.array_equal(wp[:, :, :3], w)
    assert not wp[:, :, 3:].any()
    assert np.array_equal(bp[:3], b)
    assert not bp[3:].any()


def test_arbiter_drain_split_mirrors_the_rust_governor():
    # Pinned cross-language numbers (rust governor.rs test
    # `drain_split_weights_interactive_over_batch`): budget 1000, resident
    # bases (300-100) + (260-60) = 400 -> joint headroom 600, split 3:1 ->
    # 450/150, divided by activation 100/60 -> drains 4 and 2 under
    # max_batch 8, workers 1.
    tenants = [
        {'name': 'a', 'qos': 'interactive', 'predicted': 300, 'activation': 100},
        {'name': 'b', 'qos': 'batch', 'predicted': 260, 'activation': 60},
    ]
    assert port.arbiter_drains(tenants, 1000, 8, 1) == {'a': 4, 'b': 2}
    # A single tenant reduces to the plain single-model derivation:
    # headroom 800 over activation 100 hits the max_batch/workers cap.
    solo = [tenants[0]]
    assert port.arbiter_drains(solo, 1000, 8, 1) == {'a': 8}
    assert port.arbiter_drains(solo, 1000, 8, 2) == {'a': 4}
    # Drains never drop below 1 (forward progress) even with no headroom,
    # and a zero activation prediction falls back to the cap.
    assert port.arbiter_drains(tenants, 1, 8, 1) == {'a': 1, 'b': 1}
    assert port.derive_drain(0, 0, 8, 2) == 4


def test_arbiter_victim_and_routing_mirror_the_coordinator():
    # Step-down policy: while any batch tenant is registered, only batch
    # tenants are victims — even when the batch tenant is listed second.
    tenants = [
        {'name': 'a', 'qos': 'interactive', 'rung': 2},
        {'name': 'b', 'qos': 'batch', 'rung': 1},
    ]
    assert port.step_down_victim(tenants) == 'b'
    # A batch tenant at its floor leaves nobody to step: the pool holds
    # (the interactive tenant's rung and checksums are pinned).
    tenants[1]['rung'] = 0
    assert port.step_down_victim(tenants) is None
    # Without batch tenants, interactive degrades like a single-model
    # server: first registered with a rung left.
    solo = [{'name': 'a', 'qos': 'interactive', 'rung': 2}]
    assert port.step_down_victim(solo) == 'a'
    solo[0]['rung'] = 0
    assert port.step_down_victim(solo) is None

    # Routing: a missing `model` field is the legacy id `default`; unknown
    # ids get the stable `unknown_model` code before any queue is touched.
    served = {'default', 'mobile'}
    assert port.route_model(served, {'cmd': 'infer'}) == ('default', None)
    assert port.route_model(served, {'v': 1, 'model': 'mobile'}) == ('mobile', None)
    assert port.route_model(served, {'v': 1, 'model': 'nope'}) == (None, 'unknown_model')


def test_deadline_miss_rate_pins_cross_language_numbers():
    # Pinned against rust governor.rs `deadline_miss_rate_pins_cross_
    # language_numbers`.
    assert port.deadline_miss_rate(0, 0) == 0.0
    assert port.deadline_miss_rate(7, 0) == 0.0
    assert port.deadline_miss_rate(0, 4) == 1.0
    assert port.deadline_miss_rate(3, 5) == 0.625
    assert port.deadline_miss_rate(1, 1) == 0.5
    assert port.DEADLINE_MISS_HOLD == 0.5


def test_deadline_shielded_victim_mirrors_the_governor():
    # Rust `missing_deadline_tenant_is_shielded_from_the_victim_pick`:
    # b1 registered first but missing most deadlines (3 met / 5 missed =
    # 0.625 > the 0.5 hold) is shielded while b2 has rungs to yield; once
    # b2 is at its floor, b1 — the sole candidate — steps anyway.
    tenants = [
        {'name': 'a', 'qos': 'interactive', 'rung': 2},
        {'name': 'b1', 'qos': 'batch', 'rung': 2, 'met': 3, 'missed': 5},
        {'name': 'b2', 'qos': 'batch', 'rung': 2},
    ]
    downs = []
    for _ in range(4):
        victim = port.step_down_victim(tenants)
        downs.append(victim)
        for t in tenants:
            if t['name'] == victim:
                t['rung'] -= 1
    assert downs == ['b2', 'b2', 'b1', 'b1']
    # Both batch tenants at their floors: nobody left to step, and the
    # interactive tenant was never a victim.
    assert port.step_down_victim(tenants) is None
    assert tenants[0]['rung'] == 2


def test_deadline_aware_riser_mirrors_the_governor():
    # Rust `missing_deadline_tenant_rises_first_within_its_class_only`,
    # over the same 3-rung test ladder (predicted 40/70/100 bytes,
    # activation 10/40/70).
    ladder = [40, 70, 100]

    def tenant(name, qos, rung, missed=0):
        return {
            'name': name, 'qos': qos, 'rung': rung, 'ladder': ladder,
            'predicted': ladder[rung], 'activation': [10, 40, 70][rung],
            'missed': missed,
        }

    # A missing-deadline tenant outranks its earlier-registered classmate.
    both = [tenant('a1', 'interactive', 0), tenant('a2', 'interactive', 0, missed=1)]
    assert port.step_up_riser(both, 200) == 'a2'
    # ...but misses never outrank QoS class: batch rises after interactive.
    mixed = [tenant('a', 'interactive', 0), tenant('b', 'batch', 0, missed=1)]
    assert port.step_up_riser(mixed, 200) == 'a'
    # The joint-fit check: without headroom for the next rung nobody rises.
    assert port.step_up_riser(mixed, 40) is None
    # At the top rung there is nowhere to rise to.
    assert port.step_up_riser([tenant('a', 'interactive', 2)], 10**6) is None


def test_token_bucket_mirrors_the_admission_gate():
    # Rust admission.rs `bucket_bursts_then_settles_to_the_sustained_rate`:
    # rate 2/s, burst 3 — the pinned admit sequence at t=0 and t=1.
    tokens, last = 3.0, 0.0
    seq = []
    for now in [0.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0]:
        admitted, tokens, last = port.token_bucket_admit(tokens, last, 2.0, 3.0, now)
        seq.append(admitted)
    assert seq == [True, True, True, False, True, True, False]
    # A long idle stretch refills to the burst cap, never beyond.
    assert port.token_bucket_tokens_at(tokens, last, 2.0, 3.0, 100.0) == 3.0
    # Zero rate rejects even the initial burst (rust
    # `zero_rate_rejects_even_the_initial_burst`).
    admitted, tokens, _ = port.token_bucket_admit(5.0, 0.0, 0.0, 5.0, 10.0)
    assert not admitted and tokens == 5.0
    # A clock running backwards never refills (rust
    # `clock_going_backwards_never_refills`).
    assert port.token_bucket_tokens_at(0.0, 10.0, 1.0, 2.0, 5.0) == 0.0


def test_statm_rss_scales_by_the_probed_page_size():
    # Pinned cross-language numbers (rust governor.rs test
    # `statm_parsing_scales_by_the_page_size`): the same statm line is
    # 4x/16x more resident bytes on 16K/64K-page kernels, and the parser
    # must scale by the page size it is handed — the old hardcoded 4096
    # read RSS 4-16x low and the governor never saw pressure.
    line = '5000 2048 300 20 0 1000 0\n'
    assert port.parse_statm_rss(line, 4096) == 2048 * 4096
    assert port.parse_statm_rss(line, 16384) == 2048 * 16384
    assert port.parse_statm_rss(line, 65536) == 2048 * 65536
    # Malformed lines are None, not zero; overflow never wraps.
    assert port.parse_statm_rss('', 4096) is None
    assert port.parse_statm_rss('5000', 4096) is None
    assert port.parse_statm_rss('5000 x', 4096) is None
    assert port.parse_statm_rss('1 18446744073709551615', 4096) is None


def test_watermark_band_validation_mirrors_the_governor():
    import pytest

    # The default 0.60/0.85 band at budget 100 is the (60, 85) the state
    # machine compares RSS against.
    assert port.watermark_bytes(100) == (60, 85)
    # At a 2-byte budget the same band truncates to low == high == 1:
    # every reading would be either pressure or headroom, so construction
    # rejects it (rust `watermark_bands_that_truncate_to_empty_are_rejected`).
    with pytest.raises(ValueError, match='truncates to empty'):
        port.watermark_bytes(2)
    # Degenerate fractional bands are rejected before any budget math
    # (rust `degenerate_watermarks_are_rejected_at_construction`).
    with pytest.raises(ValueError):
        port.watermark_bytes(1000, low=0.9, high=0.85)
    with pytest.raises(ValueError):
        port.watermark_bytes(1000, high=1.5)
    with pytest.raises(ValueError):
        port.watermark_bytes(1000, low=0.0)
    with pytest.raises(ValueError):
        port.watermark_bytes(1000, low=float('nan'))
    with pytest.raises(ValueError):
        port.watermark_bytes(1000, hysteresis=0)


def test_bench_protection_scoring_mirrors_the_rust_bench():
    # Pinned numbers from rust bench tests
    # `protection_stats_score_empty_windows_as_zero_isolation` and
    # `stall_rate_calibration_prices_full_overage_at_mult_baselines`.
    ws = [
        {'count': 10, 'rps': 10.0, 'p90_s': 0.100},  # full target, baseline
        {'count': 0, 'rps': 0.0, 'p90_s': 0.0},      # stalled-out window
        {'count': 5, 'rps': 5.0, 'p90_s': 0.300},    # half rps, 3x latency
    ]
    isol, lat_imp = port.protection_stats(ws, 10.0, 0.100)
    assert isol == [100.0, 0.0, 50.0]
    # The empty window contributes no latency sample.
    assert len(lat_imp) == 2
    assert abs(lat_imp[0] - 0.0) < 1e-9 and abs(lat_imp[1] - 200.0) < 1e-9
    # isol is capped at 100 even when a window beats the target.
    isol, _ = port.protection_stats(
        [{'count': 20, 'rps': 20.0, 'p90_s': 0.050}], 10.0, 0.100)
    assert isol == [100.0]
    # Stall calibration: one request over the full 16 MiB reference
    # overage stalls 3 x 40 ms; no overage or negative mult means none.
    rate = port.calibrate_stall_rate(0.040, 16 * MIB, 3.0)
    assert abs(rate * 16 * MIB - 0.12) < 1e-9
    assert port.calibrate_stall_rate(0.040, 0, 3.0) == 0.0
    assert port.calibrate_stall_rate(0.040, 1024, -1.0) == 0.0
    # Nearest-rank percentiles on the ascending sort (half away from 0).
    xs = list(range(1, 101))
    assert port.percentile_nearest_rank(xs, 0.5) == 51  # round(49.5) -> index 50
    assert port.percentile_nearest_rank(xs, 0.9) == 90
    assert port.percentile_nearest_rank(xs, 0.99) == 99
    assert port.percentile_nearest_rank([], 0.5) == 0.0
    assert port.percentile_nearest_rank([30, 10, 20], 0.5) == 20


def test_partition_tiles_pins_cross_language_chunks():
    # The exact partitions the Rust `partition_pins_exact_chunks` test
    # pins, plus the coverage/balance invariants over a small sweep.
    assert port.partition_tiles(7, 3) == [(0, 3), (3, 2), (5, 2)]
    assert port.partition_tiles(4, 8) == [(0, 1), (1, 1), (2, 1), (3, 1)]
    assert port.partition_tiles(0, 4) == []
    assert port.partition_tiles(5, 1) == [(0, 5)]
    for n in range(17):
        for t in range(1, 9):
            chunks = port.partition_tiles(n, t)
            assert len(chunks) <= t
            next_start = 0
            for start, ln in chunks:
                assert start == next_start and ln > 0, (n, t, chunks)
                next_start += ln
            assert next_start == n, (n, t, chunks)
            if chunks:
                sizes = [ln for _, ln in chunks]
                assert max(sizes) - min(sizes) <= 1, (n, t, chunks)


def test_threaded_batch_byte_identical_to_sequential():
    # The intra-worker team contract: chunked execution through the
    # sequential blocked executor, concatenated in partition order, equals
    # one call over the whole batch — for every team size including
    # threads > tiles.
    layers = tiny_layers()
    weights = gen_network_weights(layers)
    packed = pack_weights(layers, weights)
    img = gen_image(31, 16, 16, 3).reshape(16, 16, 3)
    tasks = plan_group(layers, 0, 2, 4, 4)
    by_class = {}
    for t in tasks:
        by_class.setdefault(class_key(t), []).append(t)
    multi = max(by_class.values(), key=len)
    assert len(multi) > 1, "want a real multi-tile class"
    tiles = [gather(img, t.input_rect()) for t in multi]
    sequential = run_task_batch_blocked(layers, packed, multi[0], tiles)
    for threads in range(1, len(multi) + 3):
        teamed = port.run_task_batch_blocked_threaded(
            layers, packed, multi[0], tiles, threads)
        assert len(teamed) == len(sequential), threads
        for s, o in zip(sequential, teamed):
            assert np.array_equal(s, o), threads


def test_rung_jump_pins_cross_language_numbers():
    # The governor's model-based step-down, pinned against the Rust
    # `pressure_overshoot_jumps_straight_to_the_fitting_rung` test:
    # ladder 40/70/100 MiB-ish units, budget 100 -> high watermark 85.
    ladder, high = [40, 70, 100], 85
    # Mild overshoot from the top rung: overage 10 discounts the limit to
    # 90, rung 1 (70) still fits -> single step.
    assert port.jump_down_target(ladder, 2, 95, high) == 1
    # Deep overshoot: overage 45 -> limit 55, only rung 0 fits -> the jump
    # skips rung 1 entirely.
    assert port.jump_down_target(ladder, 2, 130, high) == 0
    # Barely over: overage 1 -> limit 99, highest fit is still rung 1.
    assert port.jump_down_target(ladder, 2, 86, high) == 1
    # From the middle rung even a huge overage clamps to one rung down.
    assert port.jump_down_target(ladder, 1, 500, high) == 0
    # rung_for_limit itself: strict inequality at the boundary.
    assert port.rung_for_limit(ladder, 70) == 0
    assert port.rung_for_limit(ladder, 71) == 1
    assert port.rung_for_limit(ladder, 40) is None


def test_exec_thread_clamp_and_reprobe_cadence():
    # The oversubscription rule workers * threads <= cores...
    assert port.clamp_exec_threads(8, 2, 8) == 4
    assert port.clamp_exec_threads(2, 2, 8) == 2
    assert port.clamp_exec_threads(4, 8, 8) == 1
    assert port.clamp_exec_threads(4, 1, 2) == 2
    assert port.clamp_exec_threads(0, 1, 8) == 1
    assert port.clamp_exec_threads(3, 1, 0) == 1
    # ...and the re-probe cadence: due every K-th wake, 0 = never, pinned
    # against the Rust `reprobe_cadence_fires_every_k_wakes` test.
    assert [port.reprobe_due(w, 3) for w in range(1, 8)] == [
        False, False, True, False, False, True, False]
    assert not any(port.reprobe_due(w, 0) for w in range(1, 20))
