"""Line-by-line Python port of the Rust tiler geometry + reference executor
+ predictor peak, used to verify the PR's numerical claims without a Rust
toolchain in this container.

Mirrors:
  rust/src/ftp/traversal.rs   up_span / up_tile
  rust/src/ftp/grid.rs        Grid
  rust/src/ftp/variable.rs    group_halo / balance_spans / plan_group_balanced_searched
  rust/src/ftp/mod.rs         plan_group (even), TaskGeom.class_key
  rust/src/runtime/reference.rs conv2d / depthwise_conv2d / maxpool2d /
                              run_task / run_full
                              + the blocked fast path: pack_weights /
                              conv2d_blocked / depthwise_conv2d_blocked /
                              run_task_batch_blocked
  rust/src/predictor/mod.rs   peak_of_group_plan / predict_multi (peak ordering)
  rust/src/engine/mod.rs      gather / scatter / infer group loop
                              + the class-batched infer_batch loop
  rust/src/data/mod.rs        SplitMix64 hash -> weights/bias/image

The blocked port repeats the Rust loop nest exactly — bias-seeded
accumulator per BLOCK_W-pixel block, (fy, fx, ci)-ordered rank-1 updates
over an OC_LANES-padded out-channel axis, leaky-ReLU store — so
`test_reference_exec.py` can pin that the blocked layout's arithmetic is
bit-identical to the scalar path (same per-element f32 op order).
"""
import math
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

MIB = 1 << 20

# ---------------------------------------------------------------- network

@dataclass
class Layer:
    kind: str  # 'conv' | 'dw' | 'max'
    filters: int = 0
    size: int = 0
    stride: int = 1
    pad: int = 0
    in_w: int = 0
    in_h: int = 0
    in_c: int = 0
    out_w: int = 0
    out_h: int = 0
    out_c: int = 0

    @property
    def is_conv(self):
        return self.kind == 'conv'

    @property
    def is_dw(self):
        return self.kind == 'dw'

    def filter(self):
        return self.size

    def padding(self):
        # Both conv kinds pad; pools never do (LayerKind::padding()).
        return 0 if self.kind == 'max' else self.pad


def resolve(kind_list, in_w, in_h, in_c):
    layers = []
    w, h, c = in_w, in_h, in_c
    for k in kind_list:
        l = Layer(**k)
        l.in_w, l.in_h, l.in_c = w, h, c
        if l.kind == 'conv':
            l.out_w = (w + 2 * l.pad - l.size) // l.stride + 1
            l.out_h = (h + 2 * l.pad - l.size) // l.stride + 1
            l.out_c = l.filters
        elif l.kind == 'dw':
            # Depthwise: conv spatial arithmetic, channels preserved.
            l.out_w = (w + 2 * l.pad - l.size) // l.stride + 1
            l.out_h = (h + 2 * l.pad - l.size) // l.stride + 1
            l.out_c = c
        else:
            l.out_w = (w + l.stride - 1) // l.stride
            l.out_h = (h + l.stride - 1) // l.stride
            l.out_c = c
        layers.append(l)
        w, h, c = l.out_w, l.out_h, l.out_c
    return layers


def conv(filters, size):
    return dict(kind='conv', filters=filters, size=size, stride=1, pad=size // 2)


def dw(size):
    return dict(kind='dw', size=size, stride=1, pad=size // 2)


def maxpool():
    return dict(kind='max', size=2, stride=2)


def yolov2_16_ops():
    return [
        conv(32, 3), maxpool(), conv(64, 3), maxpool(),
        conv(128, 3), conv(64, 1), conv(128, 3), maxpool(),
        conv(256, 3), conv(128, 1), conv(256, 3), maxpool(),
        conv(512, 3), conv(256, 1), conv(512, 3), conv(256, 1),
    ]


def mobilenet_tiny_ops():
    """Mirror of network::mobilenet::mobilenet_tiny (16x16x3 input):
    stem conv, then depthwise-separable pairs around one pool."""
    return [
        conv(4, 3), dw(3), conv(8, 1), maxpool(), dw(3), conv(16, 1),
    ]

# ---------------------------------------------------------------- geometry

@dataclass
class LayerGeom:
    layer: int
    in_rect: Tuple[int, int, int, int]   # x0, y0, x1, y1
    out_rect: Tuple[int, int, int, int]
    pad: Tuple[int, int, int, int]       # left, right, top, bottom


@dataclass
class Task:
    grid_i: int
    grid_j: int
    layers: List[LayerGeom]

    def input_rect(self):
        return self.layers[0].in_rect

    def output_rect(self):
        return self.layers[-1].out_rect


def up_span(o0, o1, f, s, p, extent):
    lo = o0 * s - p
    hi = (o1 - 1) * s - p + f
    clo = max(lo, 0)
    chi = min(hi, extent)
    return clo, chi, clo - lo, hi - chi


def up_tile(layer: Layer, out):
    x0, y0, x1, y1 = out
    f = layer.size
    s = layer.stride
    p = layer.padding()
    ax0, ax1, pl, pr = up_span(x0, x1, f, s, p, layer.in_w)
    ay0, ay1, pt, pb = up_span(y0, y1, f, s, p, layer.in_h)
    return (ax0, ay0, ax1, ay1), (pl, pr, pt, pb)


def plan_from_bounds(layers, top, bottom, xs, ys):
    tasks = []
    for j in range(len(ys) - 1):
        for i in range(len(xs) - 1):
            out = (xs[i], ys[j], xs[i + 1], ys[j + 1])
            rev = []
            for l in range(bottom, top - 1, -1):
                in_rect, pad = up_tile(layers[l], out)
                rev.append(LayerGeom(l, in_rect, out, pad))
                out = in_rect
            rev.reverse()
            tasks.append(Task(i, j, rev))
    return tasks


def grid_bounds(n, extent):
    return [k * extent // n for k in range(n + 1)]


def plan_group(layers, top, bottom, n, m):
    ow, oh = layers[bottom].out_w, layers[bottom].out_h
    return plan_from_bounds(layers, top, bottom, grid_bounds(n, ow), grid_bounds(m, oh))


def group_halo(layers, top, bottom):
    # Kind-explicit (ftp::variable::group_halo): only pools rescale the
    # walk; both conv kinds contribute their halo. A kind-boolean here
    # would silently misclassify depthwise layers as pools.
    scale = 1
    halo = 0.0
    for l in range(bottom, top - 1, -1):
        spec = layers[l]
        if spec.kind == 'max':
            scale *= spec.stride
        else:
            halo += (spec.size // 2) / scale
    return math.ceil(halo)


def balance_spans(extent, n, halo):
    assert 1 <= n <= extent
    if n <= 2 or extent <= 2 * halo * n:
        return grid_bounds(n, extent)
    q = (extent - 2 * halo) // n
    widths = [q] * n
    widths[0] += halo
    widths[n - 1] += halo
    rem = extent - sum(widths)
    k = 1
    while rem > 0:
        widths[k % n] += 1
        rem -= 1
        k += 1
    bounds = [0]
    acc = 0
    for w in widths:
        acc += w
        bounds.append(acc)
    return bounds


def peak_tile_bytes(layers, tasks):
    peak = 0
    for t in tasks:
        for lg in t.layers:
            spec = layers[lg.layer]
            x0, y0, x1, y1 = lg.in_rect
            w_in, h_in = x1 - x0, y1 - y0
            ox0, oy0, ox1, oy1 = lg.out_rect
            w_out, h_out = ox1 - ox0, oy1 - oy0
            if spec.is_conv:
                scratch = w_out * h_out * spec.in_c * spec.size * spec.size // spec.stride
            elif spec.is_dw:
                # One per-channel im2col buffer reused across channels:
                # the channel factor drops from Eq. 2.1's scratch term.
                scratch = w_out * h_out * spec.size * spec.size // spec.stride
            else:
                scratch = 0
            mem = (scratch + w_out * h_out * spec.out_c + 2 * w_in * h_in * spec.in_c) * 4
            peak = max(peak, mem)
    return peak


def plan_group_balanced_searched(layers, top, bottom, n):
    ow, oh = layers[bottom].out_w, layers[bottom].out_h
    h0 = group_halo(layers, top, bottom)
    cands = sorted(set([max(h0 - 1, 0), h0, h0 + 1]))
    best = None
    for halo in cands:
        xs = balance_spans(ow, n, halo)
        ys = balance_spans(oh, n, halo)
        tasks = plan_from_bounds(layers, top, bottom, xs, ys)
        peak = peak_tile_bytes(layers, tasks)
        if best is None or peak < best[0]:
            best = (peak, tasks, xs, ys)
    return best[1], best[2], best[3]


def group_weight_bytes(layers, top, bottom):
    total = 0
    for l in range(top, bottom + 1):
        spec = layers[l]
        if spec.is_conv:
            total += spec.size * spec.size * spec.in_c * spec.filters * 4
        elif spec.is_dw:
            # One k x k filter per channel: C * k * k, not C * k * k * F.
            total += spec.size * spec.size * spec.in_c * 4
    return total


def parse_config(s):
    """'4v4/2/4x4' -> (cuts, tilings, variants)."""
    parts = s.split('/')
    if len(parts) == 2 and parts[1].lower() == 'nocut':
        parts = [parts[0]]
    def tile(p):
        if 'x' in p:
            a, b = p.split('x')
            assert a == b
            return int(a), 'even'
        if 'v' in p:
            a, b = p.split('v')
            assert a == b
            return int(a), 'balanced'
        return int(p), 'even'
    t0, v0 = tile(parts[0])
    tilings, variants, cuts = [t0], [v0], []
    i = 1
    while i < len(parts):
        cuts.append(int(parts[i]))
        t, v = tile(parts[i + 1])
        tilings.append(t)
        variants.append(v)
        i += 2
    return cuts, tilings, variants


def ranges(cuts, n_layers):
    out = []
    top = 0
    for c in cuts:
        out.append((top, c - 1))
        top = c
    out.append((top, n_layers - 1))
    return out


def plan_multi(layers, config_str):
    cuts, tilings, variants = parse_config(config_str)
    groups = []
    for (top, bottom), t, v in zip(ranges(cuts, len(layers)), tilings, variants):
        if v == 'even':
            groups.append(plan_group(layers, top, bottom, t, t))
        else:
            tasks, _, _ = plan_group_balanced_searched(layers, top, bottom, t)
            groups.append(tasks)
    return groups


def predict_multi_bytes(layers, config_str, bias=31 * MIB):
    cuts, tilings, variants = parse_config(config_str)
    best = 0
    for (top, bottom), t, v in zip(ranges(cuts, len(layers)), tilings, variants):
        if v == 'even':
            tasks = plan_group(layers, top, bottom, t, t)
        else:
            tasks, _, _ = plan_group_balanced_searched(layers, top, bottom, t)
        total = peak_tile_bytes(layers, tasks) + group_weight_bytes(layers, top, bottom) + bias
        best = max(best, total)
    return best


def task_macs(layers, task):
    total = 0
    for lg in task.layers:
        spec = layers[lg.layer]
        ox0, oy0, ox1, oy1 = lg.out_rect
        area = (ox1 - ox0) * (oy1 - oy0)
        if spec.is_conv:
            total += area * spec.size * spec.size * spec.in_c * spec.out_c
        else:
            # Depthwise and pool: no channel reduction.
            total += area * spec.out_c * spec.size * spec.size
    return total

# ---------------------------------------------------------- data (SplitMix)

MASK = (1 << 64) - 1


def _mix(z):
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return (z ^ (z >> 31)) & MASK


def hash_to_unit_f32(seed, index):
    h = _mix(seed ^ _mix((index + 0x9E3779B97F4A7C15) & MASK))
    return np.float32(np.float32(h >> 40) * np.float32(1.0 / (1 << 24)))


def gen_weights(seed, layer, count, fan_in):
    scale = np.float32(np.sqrt(np.float32(2.0) / np.float32(max(fan_in, 1))))
    layer_seed = seed ^ ((layer * 0xA24BAED4963EE407) & MASK)
    return np.array(
        [(hash_to_unit_f32(layer_seed, i) - np.float32(0.5)) * np.float32(2.0) * scale
         for i in range(count)],
        dtype=np.float32,
    )


def gen_bias(seed, layer, count):
    layer_seed = seed ^ ((layer * 0xD6E8FEB86659FD93) & MASK)
    return np.array(
        [(hash_to_unit_f32(layer_seed, i) - np.float32(0.5)) * np.float32(0.2)
         for i in range(count)],
        dtype=np.float32,
    )


def gen_image(seed, w, h, c):
    img_seed = seed ^ 0x243F6A8885A308D3
    return np.array([hash_to_unit_f32(img_seed, i) for i in range(w * h * c)],
                    dtype=np.float32)

# ------------------------------------------------------- reference executor

LEAKY = np.float32(0.1)
WEIGHT_SEED = 0x5EED0001


def gen_network_weights(layers, seed=WEIGHT_SEED):
    out = []
    for l, spec in enumerate(layers):
        if spec.is_conv:
            fan_in = spec.size * spec.size * spec.in_c
            count = fan_in * spec.filters
            w = gen_weights(seed, l, count, fan_in).reshape(
                spec.size, spec.size, spec.in_c, spec.filters)
            b = gen_bias(seed, l, spec.filters)
            out.append((w, b))
        elif spec.is_dw:
            # engine::gen_network_weights depthwise arm: fan-in is the
            # k x k window (no channel reduction), row order
            # (fy*size+fx)*in_c + ci, one bias per channel.
            fan_in = spec.size * spec.size
            count = fan_in * spec.in_c
            w = gen_weights(seed, l, count, fan_in).reshape(
                spec.size, spec.size, spec.in_c)
            b = gen_bias(seed, l, spec.in_c)
            out.append((w, b))
        else:
            out.append(None)
    return out


def conv2d(x, w, b, size, stride, pads, oh, ow):
    """Same loop structure as reference.rs: acc starts at b; for (fy, fx, ci)
    in order, acc[:] += xv * w[fy,fx,ci,:] elementwise in f32."""
    pl, pr, pt, pb = pads
    ih, iw, in_c = x.shape
    out_c = w.shape[3]
    out = np.zeros((oh, ow, out_c), dtype=np.float32)
    for oy in range(oh):
        for ox in range(ow):
            acc = b.copy()
            for fy in range(size):
                y = oy * stride + fy - pt
                if y < 0 or y >= ih:
                    continue
                for fx in range(size):
                    xx = ox * stride + fx - pl
                    if xx < 0 or xx >= iw:
                        continue
                    for ci in range(in_c):
                        acc = acc + x[y, xx, ci] * w[fy, fx, ci, :]
            out[oy, ox, :] = np.where(acc >= 0, acc, LEAKY * acc)
    return out


def depthwise_conv2d(x, w, b, size, stride, pads, oh, ow):
    """reference::depthwise_conv2d: per output element the accumulation is
    still `bias, then += x*w in (fy, fx, ci) order`, but each channel sees
    only its own k x k filter — no reduction across channels."""
    pl, pr, pt, pb = pads
    ih, iw, in_c = x.shape
    out = np.zeros((oh, ow, in_c), dtype=np.float32)
    for oy in range(oh):
        for ox in range(ow):
            acc = b.copy()
            for fy in range(size):
                y = oy * stride + fy - pt
                if y < 0 or y >= ih:
                    continue
                for fx in range(size):
                    xx = ox * stride + fx - pl
                    if xx < 0 or xx >= iw:
                        continue
                    acc = acc + x[y, xx, :] * w[fy, fx, :]
            out[oy, ox, :] = np.where(acc >= 0, acc, LEAKY * acc)
    return out


def maxpool2d(x, size, stride, oh, ow):
    ih, iw, c = x.shape
    out = np.full((oh, ow, c), -np.inf, dtype=np.float32)
    for oy in range(oh):
        for ox in range(ow):
            for fy in range(size):
                for fx in range(size):
                    out[oy, ox, :] = np.maximum(out[oy, ox, :],
                                                x[oy * stride + fy, ox * stride + fx, :])
    return out


def run_task(layers, weights, task, tile):
    x = tile
    for lg in task.layers:
        spec = layers[lg.layer]
        ox0, oy0, ox1, oy1 = lg.out_rect
        oh, ow = oy1 - oy0, ox1 - ox0
        pl, pr, pt, pb = lg.pad
        if spec.is_conv:
            w, b = weights[lg.layer]
            x = conv2d(x, w, b, spec.size, spec.stride, (pl, pr, pt, pb), oh, ow)
        elif spec.is_dw:
            w, b = weights[lg.layer]
            x = depthwise_conv2d(x, w, b, spec.size, spec.stride,
                                 (pl, pr, pt, pb), oh, ow)
        else:
            assert pl + pr + pt + pb == 0
            x = maxpool2d(x, spec.size, spec.stride, oh, ow)
    return x


def run_full(layers, weights, image_hwc):
    tasks = plan_group(layers, 0, len(layers) - 1, 1, 1)
    return run_task(layers, weights, tasks[0], image_hwc)

# ------------------------------------------- blocked fast path (reference.rs)

OC_LANES = 8
BLOCK_W = 8


def class_key(task):
    """TaskGeom::class_key: per-layer (in_w, in_h, pad4) signature."""
    sig = []
    for lg in task.layers:
        x0, y0, x1, y1 = lg.in_rect
        sig.append((x1 - x0, y1 - y0, lg.pad))
    return tuple(sig)


PACK_WEIGHTS_CALLS = 0  # mirrors reference::pack_weights_calls (test counter)


def pack_weights(layers, weights):
    """reference::pack_weights: zero-pad the out_c axis to an OC_LANES
    multiple; same (fy, fx, ci)-major row order, values untouched.

    Called once per engine_shared() — the Rust engine packs once per
    bundle and every reconfigure reuses the shared PackedWeights."""
    global PACK_WEIGHTS_CALLS
    PACK_WEIGHTS_CALLS += 1
    packed = []
    for spec, lw in zip(layers, weights):
        if lw is None:
            packed.append(None)
            continue
        w, b = lw
        if spec.is_dw:
            # PackedLayer { depthwise: true }: k*k rows of lane-padded
            # per-channel weights (no input-channel axis).
            out_c = spec.in_c
            ocp = -(-out_c // OC_LANES) * OC_LANES
            wp = np.zeros((spec.size, spec.size, ocp), dtype=np.float32)
            wp[:, :, :out_c] = w
        else:
            out_c = w.shape[3]
            ocp = -(-out_c // OC_LANES) * OC_LANES
            wp = np.zeros((spec.size, spec.size, spec.in_c, ocp), dtype=np.float32)
            wp[:, :, :, :out_c] = w
        bp = np.zeros(ocp, dtype=np.float32)
        bp[:out_c] = b
        packed.append((wp, bp, out_c))
    return packed


def conv2d_blocked(x, wp, bp, out_c, size, stride, pads, oh, ow):
    """reference::conv2d_blocked_into, loop for loop: per output element
    the accumulation is still `bias, then += x*w in (fy, fx, ci) order` —
    only the loop nest is rearranged (BLOCK_W output pixels share each
    weight row, padded lanes ride along and are dropped at the store)."""
    pl, pr, pt, pb = pads
    ih, iw, in_c = x.shape
    ocp = wp.shape[3]
    out = np.zeros((oh, ow, out_c), dtype=np.float32)
    for oy in range(oh):
        y0 = oy * stride - pt
        ox0 = 0
        while ox0 < ow:
            bw = min(BLOCK_W, ow - ox0)
            acc = np.tile(bp, (bw, 1))  # bias-seeded, padded lanes included
            for fy in range(size):
                y = y0 + fy
                if y < 0 or y >= ih:
                    continue
                for fx in range(size):
                    base = ox0 * stride + fx - pl
                    # ceil(-base / stride) for negative base (Rust div_ceil)
                    p_lo = 0 if base >= 0 else -(base // stride)
                    if base >= iw:
                        p_hi = 0
                    else:
                        p_hi = (iw - 1 - base) // stride + 1
                    p_hi = min(p_hi, bw)
                    if p_lo >= p_hi:
                        continue
                    for ci in range(in_c):
                        wrow = wp[fy, fx, ci, :]
                        for p in range(p_lo, p_hi):
                            xv = x[y, base + p * stride, ci]
                            acc[p, :] = acc[p, :] + xv * wrow
            for p in range(bw):
                v = acc[p, :out_c]
                out[oy, ox0 + p, :] = np.where(v >= 0, v, LEAKY * v)
            ox0 += bw
    return out


def depthwise_conv2d_blocked(x, wp, bp, out_c, size, stride, pads, oh, ow):
    """reference::depthwise_conv2d_blocked_into: the conv blocked skeleton
    (bias-seeded BLOCK_W accumulator, p_lo/p_hi edge clipping, fused leaky
    store) with an element-wise per-channel multiply instead of the
    cross-channel rank-1 update. Padded lanes are never touched by the
    accumulate (x has only in_c channels) and are dropped at the store."""
    pl, pr, pt, pb = pads
    ih, iw, in_c = x.shape
    out = np.zeros((oh, ow, out_c), dtype=np.float32)
    for oy in range(oh):
        y0 = oy * stride - pt
        ox0 = 0
        while ox0 < ow:
            bw = min(BLOCK_W, ow - ox0)
            acc = np.tile(bp, (bw, 1))
            for fy in range(size):
                y = y0 + fy
                if y < 0 or y >= ih:
                    continue
                for fx in range(size):
                    base = ox0 * stride + fx - pl
                    p_lo = 0 if base >= 0 else -(base // stride)
                    if base >= iw:
                        p_hi = 0
                    else:
                        p_hi = (iw - 1 - base) // stride + 1
                    p_hi = min(p_hi, bw)
                    if p_lo >= p_hi:
                        continue
                    wrow = wp[fy, fx, :in_c]
                    for p in range(p_lo, p_hi):
                        acc[p, :in_c] = acc[p, :in_c] + x[y, base + p * stride, :] * wrow
            for p in range(bw):
                v = acc[p, :out_c]
                out[oy, ox0 + p, :] = np.where(v >= 0, v, LEAKY * v)
            ox0 += bw
    return out


def run_task_batch_blocked(layers, packed, task, tiles):
    """reference::run_task_batch_blocked: one call for a batch of
    same-class tiles; each layer's weights stay hot across the batch."""
    xs = [t for t in tiles]
    for lg in task.layers:
        spec = layers[lg.layer]
        ox0, oy0, ox1, oy1 = lg.out_rect
        oh, ow = oy1 - oy0, ox1 - ox0
        pl, pr, pt, pb = lg.pad
        if spec.is_conv:
            wp, bp, out_c = packed[lg.layer]
            xs = [
                conv2d_blocked(x, wp, bp, out_c, spec.size, spec.stride,
                               (pl, pr, pt, pb), oh, ow)
                for x in xs
            ]
        elif spec.is_dw:
            wp, bp, out_c = packed[lg.layer]
            xs = [
                depthwise_conv2d_blocked(x, wp, bp, out_c, spec.size, spec.stride,
                                         (pl, pr, pt, pb), oh, ow)
                for x in xs
            ]
        else:
            assert pl + pr + pt + pb == 0
            xs = [maxpool2d(x, spec.size, spec.stride, oh, ow) for x in xs]
    return xs


def run_task_blocked(layers, packed, task, tile):
    return run_task_batch_blocked(layers, packed, task, [tile])[0]


def infer_batched(layers, weights, groups, images):
    """engine::infer_batch with a throwaway weight stage (packs on every
    call — fine for one-shot tests; engines share a stage via
    engine_shared/engine_with_shared below, like the Rust EngineShared)."""
    return infer_batched_packed(layers, pack_weights(layers, weights), groups, images)


def infer_batched_packed(layers, packed, groups, images):
    """engine::infer_batch: per group, gather every (image, task) tile of a
    shape class and execute the class in ONE blocked call, then scatter
    back per image; merge and re-tile at every cut. Weights arrive
    pre-packed (the shared weight stage) and are never repacked here."""
    inps = list(images)
    for tasks in groups:
        bottom = tasks[0].layers[-1].layer
        spec = layers[bottom]
        outs = [np.zeros((spec.out_h, spec.out_w, spec.out_c), dtype=np.float32)
                for _ in inps]
        order = sorted(range(len(tasks)),
                       key=lambda ix: ((tasks[ix].grid_i + tasks[ix].grid_j) % 2,
                                       tasks[ix].grid_j, tasks[ix].grid_i))
        class_order = []
        by_class = {}
        for ix in order:
            key = class_key(tasks[ix])
            if key not in by_class:
                by_class[key] = []
                class_order.append(key)
            by_class[key].append(ix)
        for key in class_order:
            ixs = by_class[key]
            tiles, pairs = [], []
            for img_i, inp in enumerate(inps):
                for ix in ixs:
                    tiles.append(gather(inp, tasks[ix].input_rect()))
                    pairs.append((img_i, ix))
            results = run_task_batch_blocked(layers, packed, tasks[ixs[0]], tiles)
            for (img_i, ix), res in zip(pairs, results):
                x0, y0, x1, y1 = tasks[ix].output_rect()
                outs[img_i][y0:y1, x0:x1, :] = res
        inps = outs
    return inps


def gather(m, rect):
    x0, y0, x1, y1 = rect
    return m[y0:y1, x0:x1, :].copy()


def infer(layers, weights, groups, image_hwc):
    """The engine group loop: gather -> run task -> scatter; merge at cuts."""
    inp = image_hwc
    for tasks in groups:
        bottom = tasks[0].layers[-1].layer
        spec = layers[bottom]
        out_map = np.zeros((spec.out_h, spec.out_w, spec.out_c), dtype=np.float32)
        order = sorted(range(len(tasks)),
                       key=lambda ix: ((tasks[ix].grid_i + tasks[ix].grid_j) % 2,
                                       tasks[ix].grid_j, tasks[ix].grid_i))
        for ix in order:
            t = tasks[ix]
            tile = gather(inp, t.input_rect())
            out = run_task(layers, weights, t, tile)
            x0, y0, x1, y1 = t.output_rect()
            out_map[y0:y1, x0:x1, :] = out
        inp = out_map
    return inp

# ------------------------------------- engine load/plan split (engine.rs)


def engine_shared(layers):
    """engine::EngineShared — the config-independent *weight stage*:
    weights generated and packed exactly once per bundle, shared by every
    engine and every reconfigure."""
    weights = gen_network_weights(layers)
    return {
        'layers': layers,
        'weights': weights,
        'packed': pack_weights(layers, weights),
    }


def engine_with_shared(shared, config_str):
    """engine::Engine::with_shared — the cheap per-config *plan stage*:
    only group geometry is built; the weight stage is reused."""
    return {
        'shared': shared,
        'config': config_str,
        'groups': plan_multi(shared['layers'], config_str),
    }


def engine_load(layers, config_str):
    """engine::Engine::load — weight stage + plan stage."""
    return engine_with_shared(engine_shared(layers), config_str)


def engine_reconfigure(engine, config_str):
    """engine::Engine::reconfigure — hot-swap the config by rebuilding ONLY
    the plan stage; packed weights are untouched (no pack_weights call)."""
    engine['groups'] = plan_multi(engine['shared']['layers'], config_str)
    engine['config'] = config_str


def engine_infer_batched(engine, images):
    """engine::Engine::infer_batch on a load/plan-split engine."""
    shared = engine['shared']
    return infer_batched_packed(
        shared['layers'], shared['packed'], engine['groups'], images)


# --------------------------------------------------------------------------
# coordinator::governor — the multi-tenant arbiter's pure math (PR 7).

QOS_WEIGHT = {'interactive': 3, 'batch': 1}
# Batch sorts below interactive: the sacrificial class under pressure.
QOS_ORDER = {'batch': 0, 'interactive': 1}


def derive_drain(headroom, per_image, max_batch, workers):
    """governor::derive_drain — per-wake batch drain from a headroom share:
    clamp(headroom / per_image, 1, max(1, max_batch / workers)); a zero
    per-image prediction falls back to the cap."""
    cap = max(1, max_batch // max(1, workers))
    if per_image == 0:
        return cap
    return min(cap, max(1, headroom // per_image))


def arbiter_drains(tenants, budget, max_batch, workers):
    """governor::split_drains — the joint headroom (budget minus every
    tenant's resident base = predicted - activation) shared by QoS weight
    (interactive 3 : batch 1), each share divided by the tenant's active
    activation footprint. Tenants are dicts with keys
    name/qos/predicted/activation."""
    bases = sum(t['predicted'] - t['activation'] for t in tenants)
    headroom = max(0, budget - bases)
    total_w = sum(QOS_WEIGHT[t['qos']] for t in tenants)
    return {
        t['name']: derive_drain(
            headroom * QOS_WEIGHT[t['qos']] // max(1, total_w),
            t['activation'], max_batch, workers)
        for t in tenants
    }


# Deadline weighting (PR 9): a tenant missing more than this fraction of
# its observed deadlines is shielded from the victim pick and preferred
# for the next step up within its class.
DEADLINE_MISS_HOLD = 0.5


def deadline_miss_rate(met, missed):
    """governor::deadline_miss_rate — missed / (met + missed); 0.0 with no
    observations, so deadline-free tenants behave exactly as before."""
    total = met + missed
    if total == 0:
        return 0.0
    return missed / total


def _miss_rate(t):
    return deadline_miss_rate(t.get('met', 0), t.get('missed', 0))


def step_down_victim(tenants):
    """governor::step_down_victim — among tenants of the lowest QoS class
    present with a rung left below them (tenant dicts carry a `rung`
    index), the first in registration order whose deadline-miss rate is
    at or under DEADLINE_MISS_HOLD; when every candidate is missing, the
    first candidate anyway (someone must yield). Interactive tenants are
    never victims while any batch tenant is registered. Optional dict
    keys `met`/`missed` default to 0 (the pre-deadline behaviour)."""
    sacrificial = min(QOS_ORDER[t['qos']] for t in tenants)
    candidates = [
        t for t in tenants
        if QOS_ORDER[t['qos']] == sacrificial and t['rung'] > 0
    ]
    for t in candidates:
        if _miss_rate(t) <= DEADLINE_MISS_HOLD:
            return t['name']
    return candidates[0]['name'] if candidates else None


def step_up_riser(tenants, budget):
    """governor::step_up_riser — the first tenant (interactive before
    batch; within a class, deadline-missing tenants before meeting ones;
    registration order last — the sort is stable, so without deadline
    observations this is exactly the pre-deadline order) whose next rung
    up exists and fits the budget jointly with every other tenant's
    resident base. Tenant dicts carry name/qos/rung/ladder (per-rung
    predicted bytes), predicted/activation for the active rung, and
    optional met/missed."""
    order = sorted(
        range(len(tenants)),
        key=lambda i: (
            -QOS_ORDER[tenants[i]['qos']],
            -(_miss_rate(tenants[i]) > DEADLINE_MISS_HOLD),
        ))
    for i in order:
        t = tenants[i]
        if t['rung'] + 1 >= len(t['ladder']):
            continue
        others = sum(o['predicted'] - o['activation']
                     for j, o in enumerate(tenants) if j != i)
        if others + t['ladder'][t['rung'] + 1] < budget:
            return t['name']
    return None


def route_model(served, request):
    """coordinator::process_line's model resolution — the `model` field
    (absent means the legacy id `default`) must name a served model; an
    unknown id yields the stable `unknown_model` code before any queue is
    touched. Returns (model, error_code)."""
    name = request.get('model', 'default')
    if name in served:
        return name, None
    return None, 'unknown_model'


# --------------------------------------------------------------------------
# governor RSS/watermark math and the bench protection scoring (PR 8).

U64_MAX = 2**64 - 1


def parse_statm_rss(text, page_size):
    """governor::parse_statm_rss — the resident-set field of a
    /proc/self/statm snapshot (second whitespace-separated field, in
    pages) scaled by the *probed* page size, never an assumed 4096 (16K
    and 64K pages are common on arm64 edge kernels). Malformed or
    u64-overflowing lines are None, not zero."""
    fields = text.split()
    if len(fields) < 2:
        return None
    try:
        pages = int(fields[1])
    except ValueError:
        return None
    if pages < 0 or pages > U64_MAX:
        return None
    rss = pages * page_size
    if rss > U64_MAX:
        return None  # checked_mul in the rust parser
    return rss


def watermark_bytes(budget, low=0.60, high=0.85, hysteresis=3):
    """GovernorConfig::watermark_bytes — validate the fractional band
    (finite, 0 < low < high <= 1, at least one hysteresis wake), then
    the truncated byte thresholds; a band whose integer truncation
    collapses to empty at a small budget raises instead of handing the
    governor a state machine that oscillates."""
    if not (math.isfinite(low) and math.isfinite(high)):
        raise ValueError('governor watermarks must be finite')
    if not 0.0 < high <= 1.0:
        raise ValueError('governor high watermark must be in (0, 1]')
    if low <= 0.0:
        raise ValueError('governor low watermark must be positive')
    if low >= high:
        raise ValueError('governor low watermark must be below the high')
    if hysteresis < 1:
        raise ValueError('governor hysteresis must be at least one wake')
    lo, hi = int(budget * low), int(budget * high)
    if lo >= hi:
        raise ValueError('governor watermark band truncates to empty')
    return lo, hi


def percentile_nearest_rank(xs, q):
    """bench::percentile_u64/_f64 — nearest-rank on the ascending sort:
    index round((n-1)*q), rounding half away from zero like rust."""
    if not xs:
        return 0.0
    v = sorted(xs)
    ix = int(math.floor((len(v) - 1) * q + 0.5))
    return v[min(ix, len(v) - 1)]


def protection_stats(windows, target_rps, base_lat_s):
    """bench::protection_stats — isol% = min(100, window_rps/target*100)
    for EVERY window (a stalled-out empty window scores 0, it is not
    skipped); lat-imp% = max(0, (window_p90/base_p50 - 1)*100) only over
    windows that saw completions. Windows are dicts with
    count/rps/p90_s."""
    base = max(base_lat_s, 1e-6)
    isol, lat_imp = [], []
    for w in windows:
        if target_rps > 0:
            isol.append(min(100.0, w['rps'] / target_rps * 100.0))
        else:
            isol.append(0.0)
        if w['count'] > 0:
            lat_imp.append(max(0.0, (w['p90_s'] / base - 1.0) * 100.0))
    return isol, lat_imp


def calibrate_stall_rate(base_lat_s, overage_ref, mult):
    """bench::calibrate_stall_rate — emulated paging-stall seconds per
    byte of budget overage, priced so one request over the full reference
    overage stalls `mult` baseline latencies; no overage (or a negative
    mult) means no stall."""
    if overage_ref == 0:
        return 0.0
    return max(mult, 0.0) * base_lat_s / overage_ref


# --------------------------------------------------------------------------
# coordinator::admission — the per-tenant token bucket (PR 9).


def token_bucket_tokens_at(tokens, last, rate, burst, now_s):
    """admission::TokenBucket::tokens_at — pure refill preview at now_s,
    clamped to the burst; a clock running backwards refills nothing."""
    if now_s > last:
        return min(burst, tokens + (now_s - last) * rate)
    return tokens


def token_bucket_admit(tokens, last, rate, burst, now_s):
    """admission::TokenBucket::admit_at — refill, then consume one whole
    token. Returns (admitted, tokens', last'). A zero rate rejects before
    the token check, so not even the initial burst leaks through."""
    tokens = token_bucket_tokens_at(tokens, last, rate, burst, now_s)
    last = max(last, now_s)
    if rate <= 0.0:
        return False, tokens, last
    if tokens >= 1.0:
        return True, tokens - 1.0, last
    return False, tokens, last


# --------------------------------------------------------------------------
# runtime::parallel — intra-worker tile teams (PR 10) — and the governor's
# model-based rung jump / periodic budget re-probe cadence.


def partition_tiles(n_tiles, threads):
    """parallel::partition_tiles — at most `threads` contiguous
    (start, len) chunks covering 0..n_tiles exactly once, sizes differing
    by at most one (remainder on the leading chunks), never an empty
    chunk. Pinned against the Rust `partition_pins_exact_chunks` test."""
    threads = max(threads, 1)
    base, rem = divmod(n_tiles, threads)
    chunks = []
    start = 0
    for i in range(threads):
        ln = base + (1 if i < rem else 0)
        if ln == 0:
            break  # all remaining chunks are empty too
        chunks.append((start, ln))
        start += ln
    return chunks


def run_task_batch_blocked_threaded(layers, packed, task, tiles, threads):
    """parallel::run_task_batch_blocked_threaded — the partition contract
    only: each chunk runs through the ordinary sequential blocked executor
    and the chunk outputs concatenate in partition order, so the result is
    byte-identical to one sequential call over the whole batch. (The port
    runs the chunks serially; the Rust team runs them on scoped threads
    into pre-split disjoint output regions — same arithmetic, same
    layout.)"""
    if max(threads, 1) == 1 or len(tiles) <= 1:
        return run_task_batch_blocked(layers, packed, task, tiles)
    out = []
    for start, ln in partition_tiles(len(tiles), threads):
        out.extend(run_task_batch_blocked(layers, packed, task,
                                          tiles[start:start + ln]))
    return out


def clamp_exec_threads(requested, workers, cores):
    """parallel::clamp_exec_threads — the pool-wide oversubscription rule
    workers * exec_threads <= cores, floor of one thread per engine."""
    return min(max(requested, 1), max(max(cores, 1) // max(workers, 1), 1))


def rung_for_limit(ladder, limit_bytes):
    """frontier::Ladder::rung_for_limit — the highest rung whose
    prediction is strictly under the limit (None when even the floor
    doesn't fit). `ladder` is the per-rung predicted bytes, ascending."""
    fit = None
    for i, predicted in enumerate(ladder):
        if predicted < limit_bytes:
            fit = i
    return fit


def jump_down_target(ladder, active, rss, high_bytes):
    """governor::jump_down_target — the model-based step-down: observed
    overage (rss above the high watermark) is charged against the active
    rung's prediction, and the ladder is re-searched for the rung fitting
    the discounted limit — the ladder projection of the frontier's
    fitting-branch pick. Clamped to at least one rung down so a sustained
    pressure streak always makes progress."""
    overage = max(rss - high_bytes, 0)
    limit = max(ladder[active] - overage, 0)
    fit = rung_for_limit(ladder, limit)
    return min(fit if fit is not None else 0, active - 1)


def reprobe_due(wakes, reprobe_wakes):
    """governor::on_wake's re-probe cadence — wakes count from 1, and the
    probe is due every `reprobe_wakes`-th wake; 0 disables it."""
    return reprobe_wakes > 0 and wakes % reprobe_wakes == 0
