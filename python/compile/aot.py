"""AOT pipeline: geometry JSON (from `mafat export-geometry`) -> one HLO
text module per fused tile-shape class -> `artifacts/manifest.json`.

HLO *text* is the interchange format: jax >= 0.5 serializes HloModuleProto
with 64-bit instruction ids that the xla crate's XLA (xla_extension 0.5.1)
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py there).

Usage (driven by `make artifacts`):

    python -m compile.aot --geometry ../artifacts/geometry.json \
                          --out ../artifacts
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import LayerCfg, fused_task_forward, full_forward, geoms_from_json, layers_from_json


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def weight_specs(layers, top, bottom):
    """ShapeDtypeStructs for the (w, b) pairs of conv layers in [top, bottom]."""
    specs = []
    for cfg in layers[top:bottom + 1]:
        if cfg.is_conv:
            specs.append(
                (
                    jax.ShapeDtypeStruct((cfg.size, cfg.size, cfg.in_c, cfg.out_c), jnp.float32),
                    jax.ShapeDtypeStruct((cfg.out_c,), jnp.float32),
                )
            )
    return specs


def lower_fused_class(layers, top, bottom, geoms, in_h, in_w):
    """Lower one tile-shape class to HLO text.

    The jitted signature is ``fn(x, w0, b0, w1, b1, ...)`` — positional and
    flat, so the Rust runtime feeds literals in a fixed order.
    """
    group_layers = layers[top:bottom + 1]
    in_c = group_layers[0].in_c

    def fn(x, *wb):
        weights = [(wb[2 * i], wb[2 * i + 1]) for i in range(len(wb) // 2)]
        return (fused_task_forward(x, weights, group_layers, geoms, use_pallas=True),)

    x_spec = jax.ShapeDtypeStruct((in_h, in_w, in_c), jnp.float32)
    flat = [s for pair in weight_specs(layers, top, bottom) for s in pair]
    lowered = jax.jit(fn).lower(x_spec, *flat)
    return to_hlo_text(lowered)


def lower_full(layers, in_h, in_w, in_c):
    def fn(x, *wb):
        weights = [(wb[2 * i], wb[2 * i + 1]) for i in range(len(wb) // 2)]
        return (full_forward(x, weights, layers, use_pallas=True),)

    x_spec = jax.ShapeDtypeStruct((in_h, in_w, in_c), jnp.float32)
    flat = [s for pair in weight_specs(layers, 0, len(layers) - 1) for s in pair]
    lowered = jax.jit(fn).lower(x_spec, *flat)
    return to_hlo_text(lowered)


def out_shape_of(geoms, layers, top, bottom):
    last = geoms[-1]
    return [last.out_h, last.out_w, layers[bottom].out_c]


def sanitize(cfg_name: str) -> str:
    return cfg_name.replace("/", "_").replace("x", "")


def build(geometry: dict, out_dir: str, *, verbose: bool = True) -> dict:
    """Lower every requested module; returns the manifest dict."""
    manifest_networks = []
    for net_json in geometry["networks"]:
        name = net_json["name"]
        layers = layers_from_json(net_json)
        net_dir = os.path.join(out_dir, name)
        os.makedirs(net_dir, exist_ok=True)
        mnet = {
            "name": name,
            "in_w": net_json["in_w"],
            "in_h": net_json["in_h"],
            "in_c": net_json["in_c"],
            "layers": net_json["layers"],
            "configs": [],
        }

        if net_json.get("emit_full"):
            path = os.path.join(name, "full.hlo.txt")
            if verbose:
                print(f"[aot] lowering {name}/full", file=sys.stderr)
            hlo = lower_full(layers, net_json["in_h"], net_json["in_w"], net_json["in_c"])
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(hlo)
            # Full output shape: walk the layer list.
            h, w = net_json["in_h"], net_json["in_w"]
            for cfg in layers:
                if cfg.is_conv:
                    # SAME-padded stride-1 convs preserve extent.
                    pass
                else:
                    h, w = h // cfg.stride, w // cfg.stride
            mnet["full"] = {
                "path": path,
                "in": [net_json["in_h"], net_json["in_w"], net_json["in_c"]],
                "out": [h, w, layers[-1].out_c],
            }

        for cfg_json in net_json["configs"]:
            cfg_name = cfg_json["config"]
            cfg_dir = os.path.join(name, sanitize(cfg_name))
            os.makedirs(os.path.join(out_dir, cfg_dir), exist_ok=True)
            mcfg = {"config": cfg_name, "groups": []}
            for g in cfg_json["groups"]:
                top, bottom = g["top"], g["bottom"]
                mclasses = []
                for klass in g["classes"]:
                    geoms = geoms_from_json(klass)
                    in_h, in_w = geoms[0].in_h, geoms[0].in_w
                    path = os.path.join(cfg_dir, f"g{g['gi']}_{klass['key']}.hlo.txt")
                    if verbose:
                        print(
                            f"[aot] lowering {name}/{cfg_name} g{g['gi']} "
                            f"class {klass['key']} ({in_h}x{in_w})",
                            file=sys.stderr,
                        )
                    hlo = lower_fused_class(layers, top, bottom, geoms, in_h, in_w)
                    with open(os.path.join(out_dir, path), "w") as f:
                        f.write(hlo)
                    mclasses.append(
                        {
                            "key": klass["key"],
                            "path": path,
                            "in": [in_h, in_w, layers[top].in_c],
                            "out": out_shape_of(geoms, layers, top, bottom),
                            "layers": klass["layers"],
                        }
                    )
                mgroup = {
                    "gi": g["gi"],
                    "top": top,
                    "bottom": bottom,
                    "n": g["n"],
                    "m": g["m"],
                    "classes": mclasses,
                    "tasks": g["tasks"],
                }
                # Echo tile boundaries so the Rust side can rebuild
                # variable (halo-balanced) tilings exactly.
                for bounds_key in ("xs", "ys"):
                    if bounds_key in g:
                        mgroup[bounds_key] = g[bounds_key]
                mcfg["groups"].append(mgroup)
            mnet["configs"].append(mcfg)
        manifest_networks.append(mnet)

    return {
        "version": 1,
        "geometry_sha256": hashlib.sha256(
            json.dumps(geometry, sort_keys=True).encode()
        ).hexdigest(),
        "networks": manifest_networks,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--geometry", required=True, help="geometry JSON from `mafat export-geometry`")
    ap.add_argument("--out", required=True, help="artifacts output directory")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    with open(args.geometry) as f:
        geometry = json.load(f)
    os.makedirs(args.out, exist_ok=True)
    manifest = build(geometry, args.out, verbose=not args.quiet)
    manifest_path = os.path.join(args.out, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    n_modules = sum(
        len(g["classes"])
        for net in manifest["networks"]
        for cfg in net["configs"]
        for g in cfg["groups"]
    ) + sum(1 for net in manifest["networks"] if "full" in net)
    print(f"[aot] wrote {n_modules} HLO modules + {manifest_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
