"""Layer-1 Pallas kernels: direct conv2d (+bias+leaky-ReLU) and maxpool.

TPU-idiom formulation of the paper's compute hot-spot (DESIGN.md
§Hardware-Adaptation): instead of Darknet's im2col + GEMM (whose scratch
buffer *is* the paper's Eq. 2.1 memory term), the convolution is expressed
as an im2col-free sum of F*F shifted matmuls

    out[oh, ow, :oc_blk] += x[oh + ky, ow + kx, :] @ w[ky, kx, :, oc_blk]

so each grid step is an MXU-shaped ``(OH*OW, Cin) x (Cin, OCblk)`` matmul
accumulated in f32, with no materialized scratch. The grid iterates over
output-channel blocks; ``BlockSpec`` streams one weight block per step while
the input tile stays resident in VMEM — the HBM<->VMEM schedule that
replaces the paper's CPU working-set reasoning.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowering produces plain HLO that the
Rust runtime loads (see /opt/xla-example/README.md). Real-TPU efficiency is
estimated analytically in EXPERIMENTS.md §Perf.

Layout: feature maps are HWC; weights are (F, F, Cin, Cout); biases (Cout,).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default output-channel block: one MXU lane tile. Shapes smaller than the
# block are handled by padding the weight/bias to a multiple (cheap, done at
# trace time) so the kernel body stays uniform.
OC_BLOCK = 128

LEAKY_SLOPE = 0.1


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, fh, fw, apply_act):
    """One grid step: full spatial tile x one output-channel block.

    x_ref: (IH, IW, Cin) padded input tile (VMEM-resident across steps)
    w_ref: (fh, fw, Cin, OCblk) weight block for this step
    b_ref: (OCblk,) bias block
    o_ref: (OH, OW, OCblk) output block
    """
    oh = o_ref.shape[0]
    ow = o_ref.shape[1]
    cin = x_ref.shape[2]
    acc = jnp.zeros((oh * ow, o_ref.shape[2]), dtype=jnp.float32)
    # F*F shifted matmuls: static python loop -> fully unrolled, each one an
    # MXU-shaped (OH*OW, Cin) @ (Cin, OCblk).
    for ky in range(fh):
        for kx in range(fw):
            window = x_ref[ky:ky + oh, kx:kx + ow, :].reshape(oh * ow, cin)
            wblk = w_ref[ky, kx, :, :]
            acc = acc + jnp.dot(window, wblk, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if apply_act:
        acc = jnp.where(acc >= 0, acc, LEAKY_SLOPE * acc)
    o_ref[...] = acc.reshape(oh, ow, o_ref.shape[2])


def conv2d(x, w, b, *, stride=1, pads=(0, 0, 0, 0), apply_act=True,
           oc_block=OC_BLOCK, interpret=True):
    """SAME/VALID-with-explicit-pads conv + bias + leaky ReLU as a Pallas call.

    Args:
      x: (H, W, Cin) input tile.
      w: (F, F, Cin, Cout) filter weights.
      b: (Cout,) bias.
      stride: spatial stride (the YOLOv2 prefix uses 1; pooling handles
        downsampling).
      pads: (top, bottom, left, right) explicit zero padding — non-zero only
        on image borders; interior tile edges carry real halo data.
      apply_act: apply the leaky-ReLU epilogue (Darknet conv default).

    Returns:
      (OH, OW, Cout) output tile.
    """
    if stride != 1:
        # Strided convs do not appear in the paper's 16-layer prefix; they
        # lower through the reference path to keep the kernel focused.
        from . import ref

        return ref.conv2d_ref(x, w, b, stride=stride, pads=pads, apply_act=apply_act)

    fh, fw, cin, cout = w.shape
    pt, pb, pl_, pr = pads
    xp = jnp.pad(x, ((pt, pb), (pl_, pr), (0, 0)))
    ih, iw, _ = xp.shape
    oh = ih - fh + 1
    ow = iw - fw + 1

    # Pad Cout up to a block multiple so the grid is uniform.
    oc_block = min(oc_block, max(32, 1 << (cout - 1).bit_length()))
    n_blocks = -(-cout // oc_block)
    cout_pad = n_blocks * oc_block
    if cout_pad != cout:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, cout_pad - cout)))
        b = jnp.pad(b, (0, cout_pad - cout))

    out = pl.pallas_call(
        functools.partial(_conv_kernel, fh=fh, fw=fw, apply_act=apply_act),
        grid=(n_blocks,),
        in_specs=[
            # Input tile: whole tile every step (stays in VMEM).
            pl.BlockSpec((ih, iw, cin), lambda i: (0, 0, 0)),
            # Weights: one output-channel block per step.
            pl.BlockSpec((fh, fw, cin, oc_block), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((oc_block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((oh, ow, oc_block), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, cout_pad), x.dtype),
        interpret=interpret,
    )(xp, w, b)
    return out[:, :, :cout]


def _maxpool_kernel(x_ref, o_ref, *, size):
    oh = o_ref.shape[0]
    ow = o_ref.shape[1]
    c = o_ref.shape[2]
    x = x_ref[: oh * size, : ow * size, :]
    x = x.reshape(oh, size, ow, size, c)
    o_ref[...] = jnp.max(jnp.max(x, axis=3), axis=1)


def maxpool2d(x, *, size=2, stride=2, interpret=True):
    """Non-overlapping max pool (size == stride) as a Pallas call.

    The fused-tile geometry guarantees pool input regions are always
    window-aligned and even-sized (see rust/src/ftp/traversal.rs), so no
    padding logic is needed here; the shape is asserted instead.
    """
    assert size == stride, "only non-overlapping pools appear in the prefix"
    h, w, c = x.shape
    assert h % size == 0 and w % size == 0, (
        f"pool input {h}x{w} not window-aligned - tiling geometry bug"
    )
    oh, ow = h // size, w // size
    return pl.pallas_call(
        functools.partial(_maxpool_kernel, size=size),
        grid=(1,),
        in_specs=[pl.BlockSpec((h, w, c), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((oh, ow, c), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, c), x.dtype),
        interpret=interpret,
    )(x)


def vmem_estimate_bytes(ih, iw, cin, oh, ow, oc_block, fh, fw):
    """Estimated VMEM residency of one conv grid step (f32), used by the
    DESIGN.md/EXPERIMENTS.md roofline analysis: input tile + one weight
    block + one output block + the accumulator."""
    inp = ih * iw * cin
    wblk = fh * fw * cin * oc_block
    out = oh * ow * oc_block
    acc = oh * ow * oc_block
    return 4 * (inp + wblk + out + acc)
