"""Layer-1 kernels: Pallas conv/maxpool plus their pure-jnp oracles."""

from .conv2d import conv2d, maxpool2d, vmem_estimate_bytes  # noqa: F401
from .ref import conv2d_ref, maxpool2d_ref  # noqa: F401
