"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is written with stock XLA ops (`lax.conv_general_dilated`,
`lax.reduce_window`) and no Pallas, so a kernel bug cannot hide in shared
code. Layout matches the kernels: HWC maps, (F, F, Cin, Cout) weights.
"""

import jax.numpy as jnp
from jax import lax

LEAKY_SLOPE = 0.1


def conv2d_ref(x, w, b, *, stride=1, pads=(0, 0, 0, 0), apply_act=True):
    """Reference conv + bias + leaky ReLU.

    pads is (top, bottom, left, right) explicit zero padding.
    """
    pt, pb, pl_, pr = pads
    # NHWC with a singleton batch.
    xn = x[None, ...]
    out = lax.conv_general_dilated(
        xn,
        w,
        window_strides=(stride, stride),
        padding=((pt, pb), (pl_, pr)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out[0] + b[None, None, :]
    if apply_act:
        out = jnp.where(out >= 0, out, LEAKY_SLOPE * out)
    return out


def maxpool2d_ref(x, *, size=2, stride=2):
    """Reference non-overlapping max pool over an HWC map."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(size, size, 1),
        window_strides=(stride, stride, 1),
        padding="VALID",
    )
