"""Layer-2 JAX model: the fused layer-group forward pass.

A *fused task* executes a contiguous range of conv/maxpool layers on one
input tile. Its geometry (per-layer tile shapes and explicit border pads) is
computed by the Rust tiler (`rust/src/ftp/`) and handed to the AOT pipeline
as JSON; this module turns one geometry + the layer hyperparameters into a
concrete JAX function calling the Layer-1 Pallas kernels, ready for
`jax.jit(...).lower(...)`.

Python only ever runs at build time (`make artifacts`).
"""

from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax.numpy as jnp

from .kernels import conv2d, conv2d_ref, maxpool2d, maxpool2d_ref


@dataclass(frozen=True)
class LayerCfg:
    """Hyperparameters of one layer (mirror of rust LayerKind + channels)."""

    kind: str  # "conv" | "max"
    in_c: int
    out_c: int
    size: int
    stride: int

    @property
    def is_conv(self) -> bool:
        return self.kind == "conv"


@dataclass(frozen=True)
class LayerGeom:
    """Tile geometry of one layer inside a fused task (mirror of rust
    ftp::LayerGeom): input tile extent and explicit border padding."""

    in_w: int
    in_h: int
    out_w: int
    out_h: int
    # (top, bottom, left, right)
    pads: Sequence[int]


def init_params(layers: Sequence[LayerCfg], seed: int = 0):
    """Deterministic parameters for testing (the engine generates its own
    weights in Rust with the same layout: (F, F, Cin, Cout) + (Cout,))."""
    import numpy as np

    rng = np.random.default_rng(seed)
    params = []
    for cfg in layers:
        if cfg.is_conv:
            scale = (2.0 / (cfg.size * cfg.size * cfg.in_c)) ** 0.5
            w = rng.uniform(-scale, scale, (cfg.size, cfg.size, cfg.in_c, cfg.out_c))
            b = rng.uniform(-0.1, 0.1, (cfg.out_c,))
            params.append((jnp.asarray(w, jnp.float32), jnp.asarray(b, jnp.float32)))
        else:
            params.append(None)
    return params


def fused_task_forward(x, weights, layers: Sequence[LayerCfg],
                       geoms: Optional[Sequence[LayerGeom]] = None,
                       *, use_pallas: bool = True):
    """Run one fused task: apply every layer of the group to tile `x`.

    Args:
      x: (H, W, Cin) input tile (halo included, border sides unpadded).
      weights: flat list of (w, b) for conv layers in order (pools skip).
      layers: per-layer hyperparameters, group order.
      geoms: per-layer tile geometry; when None, SAME padding on all sides
        (the untiled / full-map case).
      use_pallas: Pallas kernels (True) or the pure-jnp reference (False).

    Returns:
      (OH, OW, Cout) output tile — exactly the task's grid tile.
    """
    conv = conv2d if use_pallas else conv2d_ref
    pool = maxpool2d if use_pallas else maxpool2d_ref
    wi = 0
    for li, cfg in enumerate(layers):
        if cfg.is_conv:
            w, b = weights[wi]
            wi += 1
            if geoms is None:
                p = cfg.size // 2
                pads = (p, p, p, p)
            else:
                pads = tuple(geoms[li].pads)
            x = conv(x, w, b, stride=cfg.stride, pads=pads)
        else:
            x = pool(x, size=cfg.size, stride=cfg.stride)
        if geoms is not None:
            g = geoms[li]
            assert x.shape[0] == g.out_h and x.shape[1] == g.out_w, (
                f"layer {li}: produced {x.shape[:2]}, geometry says "
                f"({g.out_h}, {g.out_w})"
            )
    return x


def full_forward(x, weights, layers: Sequence[LayerCfg], *, use_pallas: bool = True):
    """The untiled reference forward over the whole input map (the
    verification oracle the engine compares tiled execution against)."""
    return fused_task_forward(x, weights, layers, None, use_pallas=use_pallas)


def layers_from_json(net_json) -> List[LayerCfg]:
    """Decode the Rust-exported network layer list."""
    out = []
    c = net_json["in_c"]
    for l in net_json["layers"]:
        if l["kind"] == "conv":
            out.append(LayerCfg("conv", c, l["filters"], l["size"], l["stride"]))
            c = l["filters"]
        else:
            out.append(LayerCfg("max", c, c, l["size"], l["stride"]))
    return out


def geoms_from_json(class_json) -> List[LayerGeom]:
    """Decode one tile-class geometry exported by the Rust tiler."""
    return [
        LayerGeom(
            in_w=g["in_w"],
            in_h=g["in_h"],
            out_w=g["out_w"],
            out_h=g["out_h"],
            pads=(g["pt"], g["pb"], g["pl"], g["pr"]),
        )
        for g in class_json["layers"]
    ]
