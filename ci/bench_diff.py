#!/usr/bin/env python3
"""Gate the search-scaling bench against its committed baseline.

Usage: bench_diff.py CURRENT.json BASELINE.json [--tolerance 1.0]

Fails (exit 1) when the cached planner performs more than `tolerance` times
the baseline's `plan_group` calls at any `max_groups` — the planner's
memoization guarantee regressing. Call counts are deterministic (they depend
only on the network and the binary-search probe sequence, never on timing),
so CI gates them exactly (`--tolerance 1.0`: any growth fails; a drop below
the baseline prints a tightening note). Wall-clock and frontier fields are
reported but never gated.
"""

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--tolerance", type=float, default=1.0,
                    help="fail when current > baseline * tolerance "
                         "(default 1.0: call counts are deterministic, any growth fails)")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    base_rows = {r["max_groups"]: r for r in base["per_max_groups"]}
    failed = False
    for row in cur["per_max_groups"]:
        mg = row["max_groups"]
        got = row["cached_plan_group_calls"]
        ref = base_rows.get(mg)
        if ref is None:
            print(f"max_groups={mg}: no baseline row, skipping")
            continue
        want = ref["cached_plan_group_calls"]
        limit = want * args.tolerance
        status = "REGRESSION" if got > limit else "ok"
        if got > limit:
            failed = True
        wall = row.get("cached_wall_ms")
        wall_s = f", wall {wall:.1f} ms" if isinstance(wall, (int, float)) else ""
        print(f"max_groups={mg}: cached plan_group calls {got} vs baseline {want} "
              f"(limit {limit:.0f}) -> {status}{wall_s}")
        fr = row.get("frontier_wall_ms")
        fv = row.get("frontier_variable_wall_ms")
        if isinstance(fr, (int, float)) and isinstance(fv, (int, float)):
            print(f"  frontier: {row.get('frontier_points')} points in {fr:.1f} ms | "
                  f"variable: {row.get('frontier_variable_points')} points in {fv:.1f} ms "
                  f"(informational)")
        if got < want:
            print(f"  note: improved below baseline; tighten "
                  f"rust/benches/BENCH_search.baseline.json to {got}")
    if failed:
        print(f"bench regression gate FAILED "
              f"(plan_group calls grew past baseline * {args.tolerance})")
        return 1
    print("bench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
