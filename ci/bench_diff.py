#!/usr/bin/env python3
"""Gate machine-readable bench JSON against a committed baseline.

Usage:
    bench_diff.py CURRENT.json BASELINE.json
        [--rows per_max_groups] [--row-key max_groups]
        [--metric NAME[:TOLERANCE[:DIRECTION]]]... [--tolerance 1.0]
        [--info KEY]...

Both bench files share one shape: a top-level array of row objects (the
`--rows` field), each identified by `--row-key`, carrying numeric metrics.
Every `--metric` gates one metric in every row that the baseline also has:

* DIRECTION `max` (default): FAIL when current > baseline * TOLERANCE.
  For metrics where bigger is worse — call counts, wall-clock ms.
  With TOLERANCE 1.0 the gate is exact (any growth fails), which is right
  for deterministic counters like the planner's `plan_group` calls.
* DIRECTION `min`: FAIL when current < baseline / TOLERANCE.
  For metrics where smaller is worse — speedup ratios. A wall-clock
  *ratio* is hardware-normalized, so it can be tolerance-gated in CI
  where absolute milliseconds cannot.

TOLERANCE defaults to `--tolerance` (default 1.0). Rows present in the
current file but absent from the baseline are reported and skipped, so
informational rows need no baseline entry. `--info KEY` prints extra
numeric fields per row without gating them.

CI invocations (see .github/workflows/ci.yml):

    # Search bench: deterministic plan_group call counts, gated exactly.
    bench_diff.py BENCH_search.json rust/benches/BENCH_search.baseline.json \
        --info cached_wall_ms --info frontier_wall_ms
    # (defaults: --rows per_max_groups --row-key max_groups
    #            --metric cached_plan_group_calls:1.0:max)

    # Exec bench: blocked-vs-scalar speedup, tolerance-gated.
    bench_diff.py BENCH_exec.json rust/benches/BENCH_exec.baseline.json \
        --rows per_config --row-key config --metric speedup:1.5:min \
        --info scalar_ms --info blocked_ms
"""

import argparse
import json
import sys


def parse_metric(spec: str, default_tolerance: float):
    """'name[:tolerance[:direction]]' -> (name, tolerance, direction)."""
    parts = spec.split(":")
    name = parts[0]
    tolerance = float(parts[1]) if len(parts) > 1 else default_tolerance
    direction = parts[2] if len(parts) > 2 else "max"
    if direction not in ("max", "min"):
        raise SystemExit(f"bad --metric direction {direction!r} (want max|min)")
    if tolerance < 1.0:
        raise SystemExit(f"--metric tolerance must be >= 1.0, got {tolerance}")
    return name, tolerance, direction


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--rows", default="per_max_groups",
                    help="top-level field holding the row array")
    ap.add_argument("--row-key", default="max_groups",
                    help="field identifying a row within the array")
    ap.add_argument("--metric", action="append", default=[],
                    help="NAME[:TOLERANCE[:DIRECTION]] to gate "
                         "(default: cached_plan_group_calls, direction max)")
    ap.add_argument("--tolerance", type=float, default=1.0,
                    help="default tolerance for --metric entries without one "
                         "(1.0 = exact: any regression fails)")
    ap.add_argument("--info", action="append", default=[],
                    help="extra per-row numeric fields to print, ungated")
    args = ap.parse_args()

    metrics = [parse_metric(m, args.tolerance) for m in args.metric] or [
        ("cached_plan_group_calls", args.tolerance, "max")
    ]

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    base_rows = {r[args.row_key]: r for r in base[args.rows]}
    failed = False
    compared = 0
    seen = set()
    for row in cur[args.rows]:
        rid = row[args.row_key]
        ref = base_rows.get(rid)
        if ref is None:
            print(f"{args.row_key}={rid}: no baseline row, skipping (informational)")
            continue
        seen.add(rid)
        for name, tolerance, direction in metrics:
            got = row.get(name)
            want = ref.get(name)
            if got is None or want is None:
                # A baseline row names this metric but one side lacks it:
                # that's a broken gate (renamed field / typoed --metric),
                # not an informational skip.
                print(f"{args.row_key}={rid}: metric {name} MISSING "
                      f"({'current' if got is None else 'baseline'})")
                failed = True
                continue
            compared += 1
            if direction == "max":
                limit = want * tolerance
                bad = got > limit
                bound = f"limit {limit:.2f}"
            else:
                limit = want / tolerance
                bad = got < limit
                bound = f"floor {limit:.2f}"
            status = "REGRESSION" if bad else "ok"
            failed = failed or bad
            info = "".join(
                f", {k} {row[k]:.1f}" for k in args.info
                if isinstance(row.get(k), (int, float))
            )
            print(f"{args.row_key}={rid}: {name} {got:g} vs baseline {want:g} "
                  f"({bound}) -> {status}{info}")
            if direction == "max" and got < want:
                print(f"  note: improved below baseline; consider tightening "
                      f"{args.baseline} to {got:g}")
            if direction == "min" and got > want:
                print(f"  note: improved above baseline; consider raising "
                      f"{args.baseline} to {got:g}")
    for rid in base_rows:
        if rid not in seen:
            # A baseline row the current file no longer emits: its gate
            # would silently vanish — treat as a regression, not a skip.
            print(f"{args.row_key}={rid}: baseline row MISSING from current file")
            failed = True
    if compared == 0:
        # Nothing was actually gated (baseline rows all absent from the
        # current file, or vice versa): a vacuous pass is a disabled gate.
        print("bench regression gate FAILED: no metric was compared")
        return 1
    if failed:
        print("bench regression gate FAILED")
        return 1
    print(f"bench regression gate passed ({compared} comparison(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
