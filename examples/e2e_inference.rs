//! End-to-end driver (DESIGN.md deliverable): real tiled inference through
//! all three layers — Rust coordinator -> AOT'd JAX/Pallas HLO -> PJRT —
//! on a batch of synthetic images, for several MAFAT configurations, with
//! numerical verification against the untiled oracle and a latency /
//! throughput / predicted-footprint report.
//!
//! Requires `make artifacts`. Run:
//!     cargo run --release --example e2e_inference
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use mafat::engine::Engine;
use mafat::network::MIB;
use mafat::plan::MafatConfig;
use mafat::predictor::{predict_mem, PredictorParams};

const BATCH: usize = 4;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let configs: Vec<MafatConfig> = vec![
        "1x1/NoCut".parse()?,
        "2x2/NoCut".parse()?,
        "3x3/8/2x2".parse()?,
        "5x5/8/2x2".parse()?,
        "2x2/12/2x2".parse()?,
    ];
    println!(
        "{:<12} {:>6} {:>9} {:>10} {:>11} {:>12} {:>10}",
        "config", "tasks", "verify", "mean ms", "img/s", "exec ms", "pred MB"
    );
    let params = PredictorParams::default();
    for config in configs {
        let mut engine = Engine::load(&artifacts, config)?;
        let net = engine.network().clone();

        // Verify on one image: tiled must equal untiled exactly.
        let probe = engine.synthetic_image(42);
        let err = engine.verify(&probe)?;
        anyhow::ensure!(err == 0.0, "{config}: verification error {err}");

        // Warm-up, then a timed batch.
        let warm = engine.synthetic_image(0);
        let _ = engine.infer(&warm)?;
        let mut total_ms = 0.0;
        let mut exec_ms = 0.0;
        let mut tasks = 0;
        let mut checksum = 0.0f32;
        for i in 0..BATCH {
            let image = engine.synthetic_image(1000 + i as u64);
            let (out, stats) = engine.infer(&image)?;
            total_ms += stats.total_ms;
            exec_ms += stats.execute_ms;
            tasks = stats.tasks;
            checksum += out.data.iter().sum::<f32>();
        }
        let mean = total_ms / BATCH as f64;
        let pred = predict_mem(&net, config, &params)?.total_bytes as f64 / MIB as f64;
        println!(
            "{:<12} {:>6} {:>9} {:>10.1} {:>11.2} {:>12.1} {:>10.1}",
            config.to_string(),
            tasks,
            "exact",
            mean,
            1e3 / mean,
            exec_ms / BATCH as f64,
            pred
        );
        let _ = checksum;
    }
    println!(
        "\nAll configurations produce bit-identical outputs to the untiled\n\
         oracle (paper §2.1.1: tiled computations are mathematically\n\
         equivalent). Predicted MB is Alg. 1/2 applied to the scaled\n\
         (160x160) network the engine runs; paper-scale predictions come\n\
         from `mafat predict` (see DESIGN.md §Real-execution scale)."
    );
    Ok(())
}
