//! End-to-end driver (DESIGN.md deliverable): real tiled inference through
//! the engine — for the paper's 2-group shapes *and* the k-group /
//! variable-tiling extensions — on a batch of synthetic images, with
//! numerical verification against the untiled oracle and a latency /
//! throughput / predicted-footprint report.
//!
//! Runs against `make artifacts` output when present (PJRT execution);
//! otherwise falls back through the shared
//! `runtime::export::ensure_reference_bundle` helper, which exports a
//! geometry-only reference bundle on the fly for the pure-Rust blocked
//! executor (`examples/serve.rs` uses the same helper). Run:
//!     cargo run --release --example e2e_inference
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use mafat::engine::Engine;
use mafat::network::MIB;
use mafat::plan::MultiConfig;
use mafat::predictor::{predict_multi, PredictorParams};

const BATCH: usize = 4;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let artifacts =
        mafat::runtime::export::ensure_reference_bundle(&artifacts, "mafat-e2e-example")?;
    let wanted: Vec<MultiConfig> = vec![
        "1x1/NoCut".parse()?,
        "2x2/NoCut".parse()?,
        "3x3/8/2x2".parse()?,
        "5x5/8/2x2".parse()?,
        "2x2/12/2x2".parse()?,
        "4x4/4/3x3/12/2x2".parse()?,
        "5v5/12/3v3".parse()?,
    ];
    // Stay usable on bundles compiled before the k-group/variable configs
    // joined the default set: skip what this manifest never compiled.
    let manifest = mafat::runtime::Manifest::load(std::path::Path::new(&artifacts))?;
    let mnet = manifest.sole_network()?;
    let configs: Vec<MultiConfig> = wanted
        .into_iter()
        .filter(|c| {
            let compiled = mnet.find_config(c).is_ok();
            if !compiled {
                eprintln!("skipping {c}: not compiled in this bundle (re-run the export to add it)");
            }
            compiled
        })
        .collect();
    println!(
        "{:<18} {:>6} {:>9} {:>10} {:>11} {:>12} {:>10}",
        "config", "tasks", "verify", "mean ms", "img/s", "exec ms", "pred MB"
    );
    let params = PredictorParams::default();
    for config in configs {
        let mut engine =
            Engine::load_network(std::path::Path::new(&artifacts), mnet, config.clone())?;
        let net = engine.network().clone();

        // Verify on one image: tiled must equal untiled exactly.
        let probe = engine.synthetic_image(42);
        let err = engine.verify(&probe)?;
        anyhow::ensure!(err == 0.0, "{config}: verification error {err}");

        // Warm-up, then a timed batch.
        let warm = engine.synthetic_image(0);
        let _ = engine.infer(&warm)?;
        let mut total_ms = 0.0;
        let mut exec_ms = 0.0;
        let mut tasks = 0;
        let mut checksum = 0.0f32;
        for i in 0..BATCH {
            let image = engine.synthetic_image(1000 + i as u64);
            let (out, stats) = engine.infer(&image)?;
            total_ms += stats.total_ms;
            exec_ms += stats.execute_ms;
            tasks = stats.tasks;
            checksum += out.data.iter().sum::<f32>();
        }
        let mean = total_ms / BATCH as f64;
        let pred = predict_multi(&net, &config, &params)?.total_bytes as f64 / MIB as f64;
        println!(
            "{:<18} {:>6} {:>9} {:>10.1} {:>11.2} {:>12.1} {:>10.1}",
            config.to_string(),
            tasks,
            "exact",
            mean,
            1e3 / mean,
            exec_ms / BATCH as f64,
            pred
        );
        let _ = checksum;
    }
    println!(
        "\nAll configurations — including k-group cuts and halo-balanced\n\
         variable tilings — produce bit-identical outputs to the untiled\n\
         oracle (paper §2.1.1: tiled computations are mathematically\n\
         equivalent). Predicted MB is Alg. 1/2 applied to the scaled\n\
         (160x160) network the engine runs; paper-scale predictions come\n\
         from `mafat predict` (see DESIGN.md §Real-execution scale)."
    );
    Ok(())
}
