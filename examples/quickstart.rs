//! Quickstart: the MAFAT workflow on the paper's YOLOv2-16 prefix.
//!
//! 1. Inspect the network (Table 2.1 style).
//! 2. Predict the memory footprint of a configuration (Alg. 1/2).
//! 3. Search for the best configuration under a budget (Alg. 3).
//! 4. Simulate the run on the calibrated Pi-3 memory/swap model.
//! 5. Walk the Pareto frontier of the k-group extension (memory vs. cost).
//!
//! Run: `cargo run --release --example quickstart`

use mafat::network::yolov2::yolov2_16;
use mafat::network::MIB;
use mafat::plan::{plan_config, MafatConfig};
use mafat::predictor::{predict_mem, PredictorParams};
use mafat::search::get_config;
use mafat::simulate::{simulate_config, SimOptions};

fn main() -> anyhow::Result<()> {
    // 1. The workload: the first 16 (feature-heavy) layers of YOLOv2.
    let net = yolov2_16();
    println!(
        "network: {} | {} layers | {:.1} GMAC | {:.1} MB of weights\n",
        net.name,
        net.n_layers(),
        net.total_macs() as f64 / 1e9,
        net.total_weight_bytes() as f64 / MIB as f64
    );

    // 2. Predict memory for a hand-picked configuration.
    let params = PredictorParams::default();
    let config: MafatConfig = "3x3/8/2x2".parse()?;
    let pred = predict_mem(&net, config, &params)?;
    let plan = plan_config(&net, config)?;
    println!(
        "{config}: {} fused tasks, predicted peak memory {:.1} MB \
         (driven by layer {} of group {})",
        plan.n_tasks(),
        pred.total_mb(),
        pred.peak.layer,
        pred.peak.group_index
    );

    // 3. Let Algorithm 3 pick configurations for a sweep of budgets.
    println!("\nAlgorithm 3 choices:");
    for mb in [256u64, 128, 96, 64, 32, 16] {
        let r = get_config(&net, mb * MIB, &params)?;
        println!(
            "  {mb:>4} MB -> {:<12} (predicted {:>5.1} MB{})",
            r.config.to_string(),
            r.predicted_bytes as f64 / MIB as f64,
            if r.is_fallback { ", fallback" } else { "" }
        );
    }

    // 4. Simulate the chosen config at a tight budget vs the untiled run.
    println!("\nsimulated latency at a 32 MB limit (calibrated Pi-3 model):");
    let opts = SimOptions::default().with_limit_mb(32);
    for config in ["1x1/NoCut".parse()?, get_config(&net, 32 * MIB, &params)?.config] {
        let r = simulate_config(&net, config, &opts)?;
        println!(
            "  {config:<12} {:>8.0} ms  (swap {:>5.1} s, {:>6.1} MB swapped)",
            r.latency_ms(),
            r.swap_s,
            r.swapped_mb()
        );
    }

    // 5. Beyond a single budget: the Pareto frontier of the k-group
    //    extension space shows what every additional megabyte buys
    //    (also `mafat frontier` on the CLI; the serving coordinator picks
    //    from this curve automatically when no --config is given).
    println!("\nPareto frontier (up to 3 groups, tilings 1..=5):");
    for p in mafat::search::frontier(&net, 3, 5, &params)? {
        println!(
            "  {:>6.1} MB -> {:<24} (cost {:>5.2} GMACeq)",
            p.predicted_bytes as f64 / MIB as f64,
            p.config.to_string(),
            p.cost_proxy as f64 / 1e9
        );
    }
    Ok(())
}
