//! Config explorer: sweep the full MAFAT configuration space on any
//! Darknet-style `.cfg` network and dump a CSV of predictions and
//! simulated latencies across memory limits — the tool a practitioner
//! would use to port MAFAT to a new CNN (paper §5 future work).
//!
//! Run: cargo run --release --example config_explorer [-- path/to/net.cfg]
//! (defaults to the built-in YOLOv2-16 prefix; CSV on stdout)

use mafat::network::{cfg, yolov2};
use mafat::plan::{manual_search_space, plan_config};
use mafat::predictor::{predict_mem, PredictorParams};
use mafat::simulate::{mafat_trace, run_trace, SimOptions};

const LIMITS_MB: [u64; 6] = [256, 128, 96, 64, 32, 16];

fn main() -> anyhow::Result<()> {
    let net = match std::env::args().nth(1) {
        Some(path) => cfg::load_cfg(std::path::Path::new(&path))?,
        None => yolov2::yolov2_16(),
    };
    eprintln!(
        "exploring {} ({} layers, cuts at {:?})",
        net.name,
        net.n_layers(),
        net.candidate_cuts()
    );

    let params = PredictorParams::default();
    let opts = SimOptions::default();

    // CSV header.
    print!("config,tasks,predicted_mb,peak_rss_mb");
    for mb in LIMITS_MB {
        print!(",latency_ms_at_{mb}mb");
    }
    println!();

    for config in manual_search_space(&net) {
        let plan = plan_config(&net, config)?;
        let pred = predict_mem(&net, config, &params)?;
        let steps = mafat_trace(&net, &plan, &opts);
        let free = run_trace(&steps, None, &opts.cost)?;
        print!(
            "{config},{},{:.1},{:.1}",
            plan.n_tasks(),
            pred.total_mb(),
            free.peak_rss_mb()
        );
        for mb in LIMITS_MB {
            let r = run_trace(&steps, Some(mb * (1 << 20)), &opts.cost)?;
            print!(",{:.0}", r.latency_ms());
        }
        println!();
    }
    eprintln!("done: {} configurations", manual_search_space(&net).len());
    Ok(())
}
