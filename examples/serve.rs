//! Serving demo: boots the coordinator with a small worker pool serving
//! TWO models from one process — the multi-tenant edge scenario — and
//! drives it with a mixed client load: legacy v0 requests (no `v`, no
//! `model`) at the default YOLOv2 bundle and protocol-v1 requests at the
//! MobileNet bundle, then a protocol-v2 request carrying a `deadline_ms`
//! latency budget. Prints per-request latencies and the final metrics
//! snapshot with its per-model slices.
//!
//! Runs against `make artifacts` output when present; otherwise falls
//! back through the shared `runtime::export::ensure_*_bundle` helpers
//! (same as `examples/e2e_inference.rs`), which export geometry-only
//! reference bundles on the fly and serve them with the pure-Rust
//! blocked executor. Run:
//!     cargo run --release --example serve [ARTIFACTS_DIR] [WORKERS]

use mafat::coordinator::{ModelSpec, QosClass, Server, ServerConfig};
use mafat::engine::Engine;
use mafat::jsonlite::Json;
use mafat::plan::MultiConfig;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let workers: usize = std::env::args()
        .nth(2)
        .map(|w| w.parse())
        .transpose()?
        .unwrap_or(2);
    let yolo_dir =
        mafat::runtime::export::ensure_reference_bundle(&artifacts, "mafat-serve-example")?;
    let mobile_dir = mafat::runtime::export::ensure_mobilenet_reference_bundle(
        "artifacts-mobilenet",
        "mafat-serve-example",
    )?;
    let yolo_config: MultiConfig = "3x3/8/2x2".parse()?;
    let mobile_config: MultiConfig = "3x3/9/2x2".parse()?;

    let server = Server::start_multi(
        vec![
            ModelSpec {
                name: "default".into(),
                qos: QosClass::Interactive,
                factory: Box::new(move || Engine::load(&yolo_dir, yolo_config.clone())),
            },
            ModelSpec {
                name: "mobilenet".into(),
                qos: QosClass::Batch,
                factory: Box::new(move || Engine::load(&mobile_dir, mobile_config.clone())),
            },
        ],
        "127.0.0.1:0",
        ServerConfig {
            queue_depth: 32,
            max_batch: 4,
            workers,
            ..ServerConfig::default()
        },
        None,
    )?;
    let addr = server.local_addr;
    std::thread::spawn(move || {
        let _ = server.run();
    });

    // Client load: 3 connections x 4 requests each, alternating a legacy
    // v0 request (routed to `default`) with a v1 request at `mobilenet`.
    let t0 = Instant::now();
    let handles: Vec<_> = (0..3)
        .map(|conn| {
            std::thread::spawn(move || -> anyhow::Result<Vec<(String, f64, f64)>> {
                let stream = TcpStream::connect(addr)?;
                stream.set_read_timeout(Some(Duration::from_secs(300)))?;
                let mut writer = stream.try_clone()?;
                let mut reader = BufReader::new(stream);
                let mut out = Vec::new();
                for i in 0..4 {
                    let seed = conn * 10 + i;
                    let (id, req) = if i % 2 == 0 {
                        let id = format!("c{conn}-v0-r{i}");
                        (id.clone(), format!(r#"{{"cmd":"infer","id":"{id}","seed":{seed}}}"#))
                    } else {
                        let id = format!("c{conn}-v1-r{i}");
                        (
                            id.clone(),
                            format!(
                                r#"{{"v":1,"cmd":"infer","model":"mobilenet","id":"{id}","seed":{seed}}}"#
                            ),
                        )
                    };
                    writer.write_all(req.as_bytes())?;
                    writer.write_all(b"\n")?;
                    let mut line = String::new();
                    reader.read_line(&mut line)?;
                    let j = Json::parse(&line)?;
                    anyhow::ensure!(j.get("ok")?.as_bool()?, "request failed: {line}");
                    out.push((
                        id,
                        j.get("latency_ms")?.as_f64()?,
                        j.get("queue_ms")?.as_f64()?,
                    ));
                }
                Ok(out)
            })
        })
        .collect();

    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    all.sort_by(|a, b| a.0.cmp(&b.0));
    println!("{:<12} {:>12} {:>10}", "request", "infer (ms)", "queue (ms)");
    for (id, lat, q) in &all {
        println!("{id:<12} {lat:>12.1} {q:>10.1}");
    }
    println!(
        "\n{} requests in {:.2} s wall ({:.2} req/s over a pool of {workers} worker(s))",
        all.len(),
        wall,
        all.len() as f64 / wall
    );

    // A structured error: v1 gives every failure a stable machine code.
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"v\":1,\"cmd\":\"infer\",\"model\":\"nope\",\"id\":\"x\"}\n")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = Json::parse(&line)?;
    println!(
        "\nunknown model -> error.code {:?}: {}",
        j.get("error")?.str_at("code")?,
        j.get("error")?.str_at("message")?
    );

    // Protocol v2 carries a per-request deadline. This one is generous,
    // so the server answers normally (echoing "v":2); a request whose
    // deadline has already passed when a worker drains it is dropped
    // before execution with code `deadline_exceeded`.
    let req = br#"{"v":2,"cmd":"infer","id":"d","seed":3,"deadline_ms":60000}"#;
    writer.write_all(req)?;
    writer.write_all(b"\n")?;
    line.clear();
    reader.read_line(&mut line)?;
    let j = Json::parse(&line)?;
    println!(
        "\nv2 infer with deadline_ms=60000 -> ok={} v={} in {:.1} ms",
        j.get("ok")?.as_bool()?,
        j.get("v")?.as_f64()?,
        j.get("latency_ms")?.as_f64()?
    );

    // Metrics snapshot (aggregates + per-model slices).
    writer.write_all(b"{\"cmd\":\"metrics\"}\n")?;
    line.clear();
    reader.read_line(&mut line)?;
    let j = Json::parse(&line)?;
    println!("\nserver metrics:\n{}", j.str_at("metrics")?);
    Ok(())
}
