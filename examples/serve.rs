//! Serving demo: boots the coordinator with a small worker pool, drives it
//! with a client load (mixed synthetic-image requests over several
//! connections), prints per-request latencies and the final metrics
//! snapshot — the single-device edge-serving scenario the paper's intro
//! motivates, scaled out to N engines.
//!
//! Runs against `make artifacts` output when present; otherwise falls
//! back through the shared `runtime::export::ensure_reference_bundle`
//! helper (same as `examples/e2e_inference.rs`), which exports a
//! geometry-only reference bundle on the fly and serves it with the
//! pure-Rust blocked executor. Run:
//!     cargo run --release --example serve [ARTIFACTS_DIR] [WORKERS]

use mafat::coordinator::{Server, ServerConfig};
use mafat::engine::Engine;
use mafat::jsonlite::Json;
use mafat::plan::MultiConfig;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let workers: usize = std::env::args()
        .nth(2)
        .map(|w| w.parse())
        .transpose()?
        .unwrap_or(2);
    let artifacts =
        mafat::runtime::export::ensure_reference_bundle(&artifacts, "mafat-serve-example")?;
    let config: MultiConfig = "3x3/8/2x2".parse()?;

    let server = Server::start(
        move || Engine::load(&artifacts, config.clone()),
        "127.0.0.1:0",
        ServerConfig {
            queue_depth: 32,
            max_batch: 4,
            workers,
        },
    )?;
    let addr = server.local_addr;
    std::thread::spawn(move || {
        let _ = server.run();
    });

    // Client load: 3 connections x 4 requests each.
    let t0 = Instant::now();
    let handles: Vec<_> = (0..3)
        .map(|conn| {
            std::thread::spawn(move || -> anyhow::Result<Vec<(String, f64, f64)>> {
                let stream = TcpStream::connect(addr)?;
                stream.set_read_timeout(Some(Duration::from_secs(300)))?;
                let mut writer = stream.try_clone()?;
                let mut reader = BufReader::new(stream);
                let mut out = Vec::new();
                for i in 0..4 {
                    let id = format!("c{conn}-r{i}");
                    let req = format!(r#"{{"cmd":"infer","id":"{id}","seed":{}}}"#, conn * 10 + i);
                    writer.write_all(req.as_bytes())?;
                    writer.write_all(b"\n")?;
                    let mut line = String::new();
                    reader.read_line(&mut line)?;
                    let j = Json::parse(&line)?;
                    anyhow::ensure!(j.get("ok")?.as_bool()?, "request failed: {line}");
                    out.push((
                        id,
                        j.get("latency_ms")?.as_f64()?,
                        j.get("queue_ms")?.as_f64()?,
                    ));
                }
                Ok(out)
            })
        })
        .collect();

    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    all.sort_by(|a, b| a.0.cmp(&b.0));
    println!("{:<10} {:>12} {:>10}", "request", "infer (ms)", "queue (ms)");
    for (id, lat, q) in &all {
        println!("{id:<10} {lat:>12.1} {q:>10.1}");
    }
    println!(
        "\n{} requests in {:.2} s wall ({:.2} req/s over a pool of {workers} worker(s))",
        all.len(),
        wall,
        all.len() as f64 / wall
    );

    // Metrics snapshot (aggregated across the pool).
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"cmd\":\"metrics\"}\n")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = Json::parse(&line)?;
    println!("\nserver metrics:\n{}", j.str_at("metrics")?);
    Ok(())
}
